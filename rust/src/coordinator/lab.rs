//! The declarative lab runner: resumable experiment plans over the typed
//! config registry (`mls-train lab run plan.json`).
//!
//! A **plan** is a JSON grid spec — named override axes (each axis a
//! registry key with a list of values) × seeds — expanded deterministically
//! into **trials**: fully-resolved [`TrainConfig`]s with stable ids. Each
//! trial owns one directory under the run dir:
//!
//! ```text
//!   <out>/<plan-name>/
//!     plan.json                      # provenance copy of the parsed plan
//!     t000__cnn_t__fp32__s0/
//!       trial_input.json             # resolved config + ids (before running)
//!       cnn_t_fp32_s0.csv            # metrics CSV (trainer output)
//!       cnn_t_fp32_s0.state.bin      # final parameters
//!       cnn_t_fp32_s0.audit.jsonl    # per-step audit stream (quantized runs)
//!       trial_output.json            # curves + rolled-up audit + checksum
//!     ...
//!     analysis/ranked.jsonl          # one ranked record per trial
//!     analysis/tables.md             # best-format-per-model + bitwidth frontier
//! ```
//!
//! The runner is **crash-resumable** at two granularities. A re-run
//! skips every trial whose existing `trial_output.json` parses, carries
//! the plan/trial ids, echoes the exact resolved config, and has the
//! full result shape (`schemas/trial_output.schema.json`); anything
//! else — missing, truncated mid-bytes, stale config — re-executes. And
//! a re-executed trial whose config sets `checkpoint_every` resumes
//! **at step granularity** from its last good checkpoint inside the
//! trial directory (see [`super::checkpoint`]). Trials are
//! deterministic in their seeds, so either path reproduces the output
//! bit-for-bit (everything except the wall-clock `timing` object;
//! pinned by `rust/tests/lab_runner.rs` and
//! `rust/tests/fault_tolerance.rs`).
//!
//! Everything here is stdlib-only, like the rest of the crate: the plan
//! parser sits on [`crate::util::json`], the trials run the native
//! Alg. 1 trainer ([`trainer::train_native`]), and the analysis step is
//! plain sorting + aggregation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{anyhow, ensure, Context, Result};

use super::config::{Backend, TrainConfig};
use super::trainer::{self, TrainResult};
use crate::mls::quantizer::QuantConfig;
use crate::nn::train::state_checksum;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Plan spec
// ---------------------------------------------------------------------------

/// A parsed plan: fixed base overrides, named grid axes, seeds. Axes are
/// held sorted by key (JSON object order), values in file order — the
/// expansion is a pure function of the file contents.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub name: String,
    /// fixed `key=value` overrides applied to every trial, sorted by key
    pub base: Vec<(String, String)>,
    /// grid axes: (registry key, values), sorted by key; the LAST axis
    /// varies fastest in the expansion
    pub grid: Vec<(String, Vec<String>)>,
    /// seeds swept innermost (faster than every grid axis)
    pub seeds: Vec<u64>,
}

/// Keys a plan may not override: the runner owns them per trial.
const RESERVED_KEYS: &[&str] = &["seed", "out_dir"];

fn scalar_string(key: &str, v: &Json) -> Result<String> {
    v.coerce_string()
        .ok_or_else(|| anyhow!("plan key {key:?}: values must be scalars, got {v:?}"))
}

impl Plan {
    /// Parse a plan from its JSON form (`schemas/plan.schema.json`):
    /// required `name` + `grid`; optional `base`, and `seeds` (explicit
    /// list) or `repeats` (N ⇒ seeds 0..N), default one trial per grid
    /// point at seed 0. Unknown top-level keys and reserved/unknown
    /// config keys are rejected up front.
    pub fn from_json(v: &Json) -> Result<Plan> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("plan must be a JSON object"))?;
        for k in obj.keys() {
            ensure!(
                ["name", "base", "grid", "seeds", "repeats"].contains(&k.as_str()),
                "unknown plan key {k:?} (have name, base, grid, seeds, repeats)"
            );
        }
        let name = v.req("name")?.as_str().ok_or_else(|| anyhow!("plan name must be a string"))?;
        ensure!(!name.is_empty(), "plan name must be non-empty");
        ensure!(
            name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-'),
            "plan name {name:?} must be [A-Za-z0-9_-] (it becomes the run directory)"
        );

        let mut base = Vec::new();
        if let Some(b) = v.get("base") {
            let bo = b.as_obj().ok_or_else(|| anyhow!("plan base must be an object"))?;
            for (k, val) in bo {
                check_plan_key(k)?;
                base.push((k.clone(), scalar_string(k, val)?));
            }
        }

        let go = v
            .req("grid")?
            .as_obj()
            .ok_or_else(|| anyhow!("plan grid must be an object of key: [values]"))?;
        ensure!(!go.is_empty(), "plan grid must have at least one axis");
        let mut grid = Vec::new();
        for (k, vals) in go {
            check_plan_key(k)?;
            ensure!(
                !base.iter().any(|(bk, _)| bk == k),
                "plan key {k:?} appears in both base and grid"
            );
            let arr = vals
                .as_arr()
                .ok_or_else(|| anyhow!("plan grid axis {k:?} must be an array of values"))?;
            ensure!(!arr.is_empty(), "plan grid axis {k:?} must be non-empty");
            let vals: Vec<String> =
                arr.iter().map(|x| scalar_string(k, x)).collect::<Result<_>>()?;
            grid.push((k.clone(), vals));
        }

        ensure!(
            !(obj.contains_key("seeds") && obj.contains_key("repeats")),
            "plan may set seeds or repeats, not both"
        );
        let seeds = if let Some(s) = v.get("seeds") {
            let arr = s.as_arr().ok_or_else(|| anyhow!("plan seeds must be an array"))?;
            ensure!(!arr.is_empty(), "plan seeds must be non-empty");
            arr.iter()
                .map(|x| {
                    x.as_f64()
                        .filter(|n| n.fract() == 0.0 && *n >= 0.0)
                        .map(|n| n as u64)
                        .ok_or_else(|| anyhow!("plan seeds must be non-negative integers, got {x:?}"))
                })
                .collect::<Result<Vec<u64>>>()?
        } else if let Some(r) = v.get("repeats") {
            let n = r
                .as_f64()
                .filter(|n| n.fract() == 0.0 && *n >= 1.0)
                .ok_or_else(|| anyhow!("plan repeats must be a positive integer, got {r:?}"))?
                as u64;
            (0..n).collect()
        } else {
            vec![0]
        };

        Ok(Plan { name: name.to_string(), base, grid, seeds })
    }

    /// Load a plan file.
    pub fn load(path: &Path) -> Result<Plan> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading plan {}", path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Plan::from_json(&v).with_context(|| format!("plan {}", path.display()))
    }

    /// The normalized plan as JSON (the provenance copy written into the
    /// run directory; `Plan::from_json(to_json(p)) == p`).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        if !self.base.is_empty() {
            let b = self.base.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect();
            m.insert("base".to_string(), Json::Obj(b));
        }
        let g = self
            .grid
            .iter()
            .map(|(k, vals)| {
                (k.clone(), Json::Arr(vals.iter().map(|v| Json::Str(v.clone())).collect()))
            })
            .collect();
        m.insert("grid".to_string(), Json::Obj(g));
        m.insert(
            "seeds".to_string(),
            Json::Arr(self.seeds.iter().map(|s| Json::Num(*s as f64)).collect()),
        );
        Json::Obj(m)
    }

    /// Deterministic expansion into fully-resolved trials: the grid
    /// odometer (last axis fastest) with seeds innermost. Every config is
    /// resolved through the typed registry AND validated for the native
    /// backend here, so a bad plan fails completely before any trial
    /// runs.
    pub fn trials(&self) -> Result<Vec<Trial>> {
        let mut out = Vec::new();
        let axes: Vec<usize> = self.grid.iter().map(|(_, v)| v.len()).collect();
        let combos: usize = axes.iter().product::<usize>() * self.seeds.len();
        let mut idx = vec![0usize; axes.len()];
        loop {
            let bindings: Vec<(String, String)> = self
                .grid
                .iter()
                .zip(&idx)
                .map(|((k, vals), &i)| (k.clone(), vals[i].clone()))
                .collect();
            for &seed in &self.seeds {
                let index = out.len();
                let mut config = TrainConfig::default();
                for (k, v) in self.base.iter().chain(&bindings) {
                    config.set_key(k, v).with_context(|| format!("plan {:?}", self.name))?;
                }
                config.seed = seed;
                ensure!(
                    config.backend == Backend::Native,
                    "lab plans run the native backend only (trial {index} asks for {:?})",
                    config.backend.name()
                );
                trainer::validate_native_config(&config)
                    .with_context(|| format!("plan {:?} trial {index}", self.name))?;
                let id = format!(
                    "t{index:03}__{}__{}__s{seed}",
                    config.model, config.cfg_name
                );
                out.push(Trial { id, index, seed, bindings: bindings.clone(), config });
            }
            // odometer: bump the last axis, carry left
            let mut pos = idx.len();
            loop {
                if pos == 0 {
                    ensure!(out.len() == combos, "expansion bug: {} != {combos}", out.len());
                    return Ok(out);
                }
                pos -= 1;
                idx[pos] += 1;
                if idx[pos] < axes[pos] {
                    break;
                }
                idx[pos] = 0;
            }
        }
    }
}

fn check_plan_key(k: &str) -> Result<()> {
    ensure!(
        !RESERVED_KEYS.contains(&super::config::canonical_key(k)),
        "plan key {k:?} is reserved: the lab runner assigns it per trial \
         (seeds via the plan's seeds/repeats, out_dir per trial directory)"
    );
    ensure!(
        super::config::key_spec(k).is_some(),
        "unknown config key {k:?} in plan\n{}",
        super::config::help_table()
    );
    Ok(())
}

/// One fully-resolved trial of a plan.
#[derive(Clone, Debug)]
pub struct Trial {
    /// stable id, also the trial directory name:
    /// `t<index>__<model>__<cfg>__s<seed>`
    pub id: String,
    pub index: usize,
    pub seed: u64,
    /// this trial's grid-axis values (key, value)
    pub bindings: Vec<(String, String)>,
    pub config: TrainConfig,
}

impl Trial {
    /// `trial_input.json`: the ids plus the fully-resolved config,
    /// written BEFORE the trial runs so a crashed run still records what
    /// it was doing.
    pub fn input_json(&self, plan: &Plan) -> Json {
        let mut m = BTreeMap::new();
        m.insert("plan".to_string(), Json::Str(plan.name.clone()));
        m.insert("trial".to_string(), Json::Str(self.id.clone()));
        m.insert("index".to_string(), Json::Num(self.index as f64));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        m.insert(
            "base".to_string(),
            Json::Obj(plan.base.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect()),
        );
        m.insert(
            "bindings".to_string(),
            Json::Obj(
                self.bindings.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect(),
            ),
        );
        m.insert("config".to_string(), self.config.to_json());
        Json::Obj(m)
    }
}

// ---------------------------------------------------------------------------
// Trial outputs
// ---------------------------------------------------------------------------

fn num_or_null(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// Build `trial_output.json` (`schemas/trial_output.schema.json`) from a
/// finished run. Everything outside the `timing` object is a pure
/// function of the resolved config — re-running a trial reproduces it
/// bit-for-bit (the crash-resume test's invariant).
fn output_json(plan: &Plan, trial: &Trial, r: &TrainResult, total_ms: f64) -> Json {
    let mut result = BTreeMap::new();
    result.insert(
        "status".to_string(),
        Json::Str(if r.diverged { "diverged" } else { "ok" }.to_string()),
    );
    result.insert("steps_run".to_string(), Json::Num(r.metrics.steps.len() as f64));
    result.insert("final_loss".to_string(), num_or_null(r.metrics.final_loss(20)));
    result.insert("test_loss".to_string(), num_or_null(r.test_loss as f64));
    result.insert("test_acc".to_string(), num_or_null(r.test_acc as f64));
    result.insert(
        "loss_curve".to_string(),
        Json::Arr(r.metrics.steps.iter().map(|s| num_or_null(s.loss as f64)).collect()),
    );
    result.insert(
        "acc_curve".to_string(),
        Json::Arr(r.metrics.steps.iter().map(|s| num_or_null(s.acc as f64)).collect()),
    );
    result.insert(
        "eval".to_string(),
        Json::Arr(
            r.metrics
                .evals
                .iter()
                .map(|e| {
                    let mut em = BTreeMap::new();
                    em.insert("step".to_string(), Json::Num(e.step as f64));
                    em.insert("loss".to_string(), num_or_null(e.loss as f64));
                    em.insert("acc".to_string(), num_or_null(e.acc as f64));
                    Json::Obj(em)
                })
                .collect(),
        ),
    );
    result.insert("audit_steps".to_string(), Json::Num(r.audit_steps as f64));
    if r.audit_steps > 0 {
        result.insert("audit_totals".to_string(), r.audit_totals.totals_json());
    }
    result.insert(
        "state_checksum".to_string(),
        Json::Str(format!("{:016x}", state_checksum(&r.final_state))),
    );

    // steps_executed / resumed live under `timing`, the one object
    // excluded from bit-identity: a resumed trial executes fewer steps
    // than a fresh one, while producing the identical `result`
    let mut timing = BTreeMap::new();
    timing.insert("mean_step_ms".to_string(), num_or_null(r.metrics.mean_step_ms()));
    timing.insert("total_ms".to_string(), num_or_null(total_ms));
    timing.insert("steps_executed".to_string(), Json::Num(r.steps_executed as f64));
    if let Some(from) = r.resumed_from {
        timing.insert("resumed".to_string(), Json::Num(from as f64));
    }

    let mut m = BTreeMap::new();
    m.insert("plan".to_string(), Json::Str(plan.name.clone()));
    m.insert("trial".to_string(), Json::Str(trial.id.clone()));
    m.insert("index".to_string(), Json::Num(trial.index as f64));
    m.insert("seed".to_string(), Json::Num(trial.seed as f64));
    m.insert("config".to_string(), trial.config.to_json());
    m.insert("result".to_string(), Json::Obj(result));
    m.insert("timing".to_string(), Json::Obj(timing));
    Json::Obj(m)
}

/// Decide whether an existing `trial_output.json` makes its trial
/// skippable: it must carry this plan's and trial's ids, echo the exact
/// resolved config the plan expands to today, and have the full result
/// shape of `schemas/trial_output.schema.json`. A truncated file fails
/// the JSON parse upstream; a stale config (plan edited since) fails the
/// echo comparison — both re-execute.
pub fn validate_trial_output(v: &Json, plan: &Plan, trial: &Trial) -> Result<()> {
    ensure!(v.req("plan")?.as_str() == Some(&plan.name), "plan id mismatch");
    ensure!(v.req("trial")?.as_str() == Some(&trial.id), "trial id mismatch");
    ensure!(v.req("index")?.as_usize() == Some(trial.index), "trial index mismatch");
    ensure!(
        *v.req("config")? == trial.config.to_json(),
        "resolved config changed since this output was written"
    );
    let r = v.req("result")?;
    let status = r.req("status")?.as_str().unwrap_or("");
    ensure!(status == "ok" || status == "diverged", "bad result.status {status:?}");
    r.req("steps_run")?.as_f64().ok_or_else(|| anyhow!("result.steps_run not a number"))?;
    for k in ["final_loss", "test_loss", "test_acc"] {
        r.req(k)?; // number, or null for a diverged run
    }
    for k in ["loss_curve", "acc_curve", "eval"] {
        r.req(k)?.as_arr().ok_or_else(|| anyhow!("result.{k} not an array"))?;
    }
    r.req("audit_steps")?.as_f64().ok_or_else(|| anyhow!("result.audit_steps not a number"))?;
    r.req("state_checksum")?
        .as_str()
        .ok_or_else(|| anyhow!("result.state_checksum not a string"))?;
    let t = v.req("timing")?;
    for k in ["mean_step_ms", "total_ms"] {
        t.req(k)?;
    }
    t.req("steps_executed")?
        .as_f64()
        .ok_or_else(|| anyhow!("timing.steps_executed not a number"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Runner
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrialStatus {
    Ran,
    Skipped,
}

/// What a `lab run` did: per-trial statuses plus where everything landed.
#[derive(Debug)]
pub struct LabReport {
    pub plan_name: String,
    pub run_dir: PathBuf,
    pub statuses: Vec<(String, TrialStatus)>,
    pub analysis_dir: PathBuf,
}

impl LabReport {
    pub fn ran(&self) -> usize {
        self.statuses.iter().filter(|(_, s)| *s == TrialStatus::Ran).count()
    }

    pub fn skipped(&self) -> usize {
        self.statuses.iter().filter(|(_, s)| *s == TrialStatus::Skipped).count()
    }

    /// One-line summary (CI greps the "ran N, skipped M" counts to prove
    /// resume worked).
    pub fn summary(&self) -> String {
        format!(
            "plan {}: {} trials — ran {}, skipped {} — {}",
            self.plan_name,
            self.statuses.len(),
            self.ran(),
            self.skipped(),
            self.run_dir.display()
        )
    }
}

/// Durable atomic write: tmp file, fsync, rename, fsync parent dir —
/// a crash at any point leaves either the old file or the new one,
/// never a torn or unsynced write ([`crate::util::fsio::write_atomic`]).
fn write_atomic(path: &Path, text: &str) -> Result<()> {
    crate::util::fsio::write_atomic(path, text.as_bytes())
}

/// Run a plan file end to end: expand, execute (or skip) every trial,
/// then rebuild the analysis tables. `force` re-executes everything.
pub fn run_plan_file(plan_path: &Path, out_root: &Path, force: bool) -> Result<LabReport> {
    let plan = Plan::load(plan_path)?;
    run_plan(&plan, out_root, force)
}

pub fn run_plan(plan: &Plan, out_root: &Path, force: bool) -> Result<LabReport> {
    run_plan_opts(plan, out_root, force, None)
}

/// [`run_plan`] with a deterministic fault injected into every trial
/// (`<site>@step<k>[:seed]`, see [`crate::util::fault`]) — the test
/// harness behind crash/resume coverage at trial granularity. The fault
/// spec never enters the config echo, so a crashed faulted trial and
/// its clean resume validate against the same `trial_output.json`.
pub fn run_plan_opts(
    plan: &Plan,
    out_root: &Path,
    force: bool,
    fault: Option<&str>,
) -> Result<LabReport> {
    let trials = plan.trials()?;
    let run_dir = out_root.join(&plan.name);
    std::fs::create_dir_all(&run_dir)?;
    // provenance: the normalized plan this run directory was built from
    write_atomic(&run_dir.join("plan.json"), &plan.to_json().to_string_pretty())?;

    let mut statuses = Vec::new();
    for trial in &trials {
        let trial_dir = run_dir.join(&trial.id);
        let out_path = trial_dir.join("trial_output.json");

        if !force {
            if let Ok(text) = std::fs::read_to_string(&out_path) {
                let valid = Json::parse(&text)
                    .map_err(anyhow::Error::from)
                    .and_then(|v| validate_trial_output(&v, plan, trial));
                match valid {
                    Ok(()) => {
                        eprintln!(
                            "[lab {}/{}] {}  skipped (valid output)",
                            trial.index + 1,
                            trials.len(),
                            trial.id
                        );
                        statuses.push((trial.id.clone(), TrialStatus::Skipped));
                        continue;
                    }
                    Err(e) => eprintln!(
                        "[lab {}/{}] {}  stale output ({e:#}) — re-running",
                        trial.index + 1,
                        trials.len(),
                        trial.id
                    ),
                }
            }
        }

        std::fs::create_dir_all(&trial_dir)?;
        let mut config = trial.config.clone();
        config.out_dir = Some(trial_dir.to_string_lossy().into_owned());
        config.fault = fault.map(str::to_string);
        if force {
            // a forced re-run starts from scratch: drop any step
            // checkpoints so the trainer cannot resume mid-trial
            super::checkpoint::CheckpointIo::new(&trial_dir, &trainer::run_tag(&config))
                .remove_all()?;
        }
        write_atomic(
            &trial_dir.join("trial_input.json"),
            &trial.input_json(plan).to_string_pretty(),
        )?;

        eprintln!(
            "[lab {}/{}] {}  running ({} steps)...",
            trial.index + 1,
            trials.len(),
            trial.id,
            config.steps
        );
        let t0 = Instant::now();
        let result =
            trainer::train_native(&config).with_context(|| format!("trial {}", trial.id))?;
        let total_ms = t0.elapsed().as_secs_f64() * 1e3;
        let out = output_json(plan, trial, &result, total_ms);
        write_atomic(&out_path, &out.to_string_pretty())?;
        eprintln!(
            "[lab {}/{}] {}  done: test-acc {:.3}{} ({:.1}s)",
            trial.index + 1,
            trials.len(),
            trial.id,
            result.test_acc,
            if result.diverged { " [DIVERGED]" } else { "" },
            total_ms / 1e3
        );
        statuses.push((trial.id.clone(), TrialStatus::Ran));
    }

    let analysis_dir = analyze(&run_dir)?;
    Ok(LabReport { plan_name: plan.name.clone(), run_dir, statuses, analysis_dir })
}

// ---------------------------------------------------------------------------
// Analysis
// ---------------------------------------------------------------------------

/// One analyzed trial row, pulled back out of a `trial_output.json`.
#[derive(Clone, Debug)]
struct Row {
    trial: String,
    model: String,
    cfg: String,
    optimizer: String,
    seed: u64,
    /// stored bits per element (32 for fp32)
    bits: u32,
    status: String,
    test_acc: Option<f64>,
    test_loss: Option<f64>,
    final_loss: Option<f64>,
    mean_step_ms: Option<f64>,
}

fn read_row(v: &Json) -> Result<Row> {
    let c = v.req("config")?;
    let cfg = c.req("cfg")?.as_str().unwrap_or_default().to_string();
    let bits = if cfg == "fp32" {
        32
    } else {
        QuantConfig::parse_name(&cfg).map(|q| q.element_bits()).unwrap_or(0)
    };
    let r = v.req("result")?;
    Ok(Row {
        trial: v.req("trial")?.as_str().unwrap_or_default().to_string(),
        model: c.req("model")?.as_str().unwrap_or_default().to_string(),
        cfg,
        optimizer: c.req("optimizer")?.as_str().unwrap_or_default().to_string(),
        seed: v.req("seed")?.as_f64().unwrap_or(0.0) as u64,
        bits,
        status: r.req("status")?.as_str().unwrap_or_default().to_string(),
        test_acc: r.req("test_acc")?.as_f64(),
        test_loss: r.req("test_loss")?.as_f64(),
        final_loss: r.req("final_loss")?.as_f64(),
        mean_step_ms: v.req("timing")?.req("mean_step_ms")?.as_f64(),
    })
}

fn fmt_opt(v: Option<f64>, prec: usize) -> String {
    v.map(|x| format!("{x:.prec$}")).unwrap_or_else(|| "—".to_string())
}

/// Mean over the present values (diverged trials report null acc and are
/// excluded from aggregates but listed in the ranking).
fn mean_opt(vals: &[Option<f64>]) -> Option<f64> {
    let present: Vec<f64> = vals.iter().flatten().copied().collect();
    if present.is_empty() {
        None
    } else {
        Some(present.iter().sum::<f64>() / present.len() as f64)
    }
}

/// Rebuild `analysis/` from every `*/trial_output.json` under a run dir:
/// `ranked.jsonl` (all trials, best test accuracy first, diverged last)
/// and `tables.md` (ranked table, best format per model, and the
/// accuracy-vs-bitwidth frontier). Pure aggregation — safe to re-run any
/// time, including over a partially-finished run directory.
pub fn analyze(run_dir: &Path) -> Result<PathBuf> {
    let mut rows = Vec::new();
    let mut entries: Vec<PathBuf> = std::fs::read_dir(run_dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.is_dir())
        .collect();
    entries.sort();
    for dir in entries {
        let path = dir.join("trial_output.json");
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        let v = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        rows.push(read_row(&v).with_context(|| path.display().to_string())?);
    }
    ensure!(!rows.is_empty(), "no trial_output.json under {}", run_dir.display());

    // rank: finished trials by test accuracy (desc), diverged trials
    // last; ties broken by trial id for a stable order
    rows.sort_by(|a, b| {
        let ka = (a.status != "ok", std::cmp::Reverse(a.test_acc.map(F64Ord))) ;
        let kb = (b.status != "ok", std::cmp::Reverse(b.test_acc.map(F64Ord)));
        ka.cmp(&kb).then_with(|| a.trial.cmp(&b.trial))
    });

    let analysis_dir = run_dir.join("analysis");
    std::fs::create_dir_all(&analysis_dir)?;

    let mut jsonl = String::new();
    for (rank, r) in rows.iter().enumerate() {
        let mut m = BTreeMap::new();
        m.insert("rank".to_string(), Json::Num((rank + 1) as f64));
        m.insert("trial".to_string(), Json::Str(r.trial.clone()));
        m.insert("model".to_string(), Json::Str(r.model.clone()));
        m.insert("cfg".to_string(), Json::Str(r.cfg.clone()));
        m.insert("optimizer".to_string(), Json::Str(r.optimizer.clone()));
        m.insert("seed".to_string(), Json::Num(r.seed as f64));
        m.insert("bits".to_string(), Json::Num(r.bits as f64));
        m.insert("status".to_string(), Json::Str(r.status.clone()));
        m.insert("test_acc".to_string(), r.test_acc.map(Json::Num).unwrap_or(Json::Null));
        m.insert("test_loss".to_string(), r.test_loss.map(Json::Num).unwrap_or(Json::Null));
        m.insert("final_loss".to_string(), r.final_loss.map(Json::Num).unwrap_or(Json::Null));
        m.insert(
            "mean_step_ms".to_string(),
            r.mean_step_ms.map(Json::Num).unwrap_or(Json::Null),
        );
        jsonl.push_str(&Json::Obj(m).to_string_compact());
        jsonl.push('\n');
    }
    std::fs::write(analysis_dir.join("ranked.jsonl"), jsonl)?;

    std::fs::write(analysis_dir.join("tables.md"), tables_md(run_dir, &rows))?;
    Ok(analysis_dir)
}

/// f64 with a total order (NaN never reaches it: rows hold Options).
#[derive(PartialEq)]
struct F64Ord(f64);
impl Eq for F64Ord {}
impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

fn tables_md(run_dir: &Path, rows: &[Row]) -> String {
    let mut md = String::new();
    md.push_str(&format!("# Lab analysis — {}\n\n", run_dir.display()));

    md.push_str("## Ranked trials\n\n");
    md.push_str("| rank | trial | model | cfg | optimizer | seed | bits | test acc | test loss | step ms |\n");
    md.push_str("|---|---|---|---|---|---|---|---|---|---|\n");
    for (rank, r) in rows.iter().enumerate() {
        md.push_str(&format!(
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |\n",
            rank + 1,
            r.trial,
            r.model,
            r.cfg,
            r.optimizer,
            r.seed,
            r.bits,
            if r.status == "ok" { fmt_opt(r.test_acc, 4) } else { "Div.".to_string() },
            fmt_opt(r.test_loss, 4),
            fmt_opt(r.mean_step_ms, 1),
        ));
    }

    // aggregate: mean test acc per (model, cfg) over seeds and optimizers
    let mut agg: BTreeMap<(String, String), Vec<Option<f64>>> = BTreeMap::new();
    for r in rows {
        agg.entry((r.model.clone(), r.cfg.clone()))
            .or_default()
            .push(if r.status == "ok" { r.test_acc } else { None });
    }
    let models: Vec<String> = {
        let mut m: Vec<String> = agg.keys().map(|(model, _)| model.clone()).collect();
        m.dedup();
        m
    };

    md.push_str("\n## Best format per model\n\n");
    md.push_str("(mean test accuracy over seeds and optimizers; Δ vs the model's fp32 mean)\n\n");
    md.push_str("| model | cfg | bits | mean acc | Δ vs fp32 | |\n");
    md.push_str("|---|---|---|---|---|---|\n");
    for model in &models {
        let fp32 = agg.get(&(model.clone(), "fp32".to_string())).and_then(|v| mean_opt(v));
        let mut cfgs: Vec<(&str, Option<f64>)> = agg
            .iter()
            .filter(|((m, _), _)| m == model)
            .map(|((_, c), v)| (c.as_str(), mean_opt(v)))
            .collect();
        cfgs.sort_by(|a, b| {
            b.1.map(F64Ord).cmp(&a.1.map(F64Ord)).then_with(|| a.0.cmp(b.0))
        });
        for (i, (cfg, acc)) in cfgs.iter().enumerate() {
            let bits = bits_of(cfg);
            let delta = match (acc, fp32) {
                (Some(a), Some(f)) if *cfg != "fp32" => format!("{:+.4}", a - f),
                _ => "—".to_string(),
            };
            md.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} |\n",
                model,
                cfg,
                bits,
                fmt_opt(*acc, 4),
                delta,
                if i == 0 { "**best**" } else { "" },
            ));
        }
    }

    md.push_str("\n## Accuracy-vs-bitwidth frontier\n\n");
    md.push_str("(per model: the best mean accuracy at each element bitwidth; \"≤1%\" marks \
configs within one point of the model's fp32 mean — the paper's Table II criterion)\n\n");
    md.push_str("| model | bits | best cfg | mean acc | Δ vs fp32 | ≤1% |\n");
    md.push_str("|---|---|---|---|---|---|\n");
    for model in &models {
        let fp32 = agg.get(&(model.clone(), "fp32".to_string())).and_then(|v| mean_opt(v));
        let mut frontier: BTreeMap<u32, (&str, Option<f64>)> = BTreeMap::new();
        for ((m, cfg), vals) in &agg {
            if m != model {
                continue;
            }
            let acc = mean_opt(vals);
            let bits = bits_of(cfg);
            let e = frontier.entry(bits).or_insert((cfg.as_str(), acc));
            if acc.map(F64Ord) > e.1.map(F64Ord) {
                *e = (cfg.as_str(), acc);
            }
        }
        for (bits, (cfg, acc)) in frontier.iter().rev() {
            let (delta, within) = match (acc, fp32) {
                (Some(a), Some(f)) if *cfg != "fp32" => {
                    (format!("{:+.4}", a - f), if f - a <= 0.01 { "yes" } else { "no" })
                }
                _ => ("—".to_string(), "—"),
            };
            md.push_str(&format!(
                "| {} | {} | {} | {} | {} | {} |\n",
                model,
                bits,
                cfg,
                fmt_opt(*acc, 4),
                delta,
                within,
            ));
        }
    }
    md
}

fn bits_of(cfg: &str) -> u32 {
    if cfg == "fp32" {
        32
    } else {
        QuantConfig::parse_name(cfg).map(|q| q.element_bits()).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plan_json(text: &str) -> Json {
        Json::parse(text).unwrap()
    }

    #[test]
    fn plan_parses_and_round_trips() {
        let p = Plan::from_json(&plan_json(
            r#"{"name": "p", "base": {"steps": 5}, "grid": {"model": ["cnn_t"], "cfg": ["fp32", "e2m4_gnc_eg8mg1_sr"]}, "seeds": [0, 1]}"#,
        ))
        .unwrap();
        assert_eq!(p.name, "p");
        assert_eq!(p.base, vec![("steps".to_string(), "5".to_string())]);
        assert_eq!(p.seeds, vec![0, 1]);
        // axes are sorted by key: cfg before model
        assert_eq!(p.grid[0].0, "cfg");
        assert_eq!(p.grid[1].0, "model");
        assert_eq!(Plan::from_json(&p.to_json()).unwrap(), p);
    }

    #[test]
    fn plan_rejects_bad_shapes() {
        for bad in [
            r#"{"grid": {"model": ["cnn_t"]}}"#,                      // no name
            r#"{"name": "p"}"#,                                       // no grid
            r#"{"name": "p", "grid": {}}"#,                           // empty grid
            r#"{"name": "p", "grid": {"model": []}}"#,                // empty axis
            r#"{"name": "p", "grid": {"model": ["cnn_t"]}, "x": 1}"#, // unknown plan key
            r#"{"name": "p", "grid": {"seed": [1]}}"#,                // reserved key
            r#"{"name": "p", "grid": {"out_dir": ["x"]}}"#,           // reserved key
            r#"{"name": "p", "grid": {"model": ["cnn_t"]}, "seeds": [1], "repeats": 2}"#,
            r#"{"name": "p", "grid": {"model": ["cnn_t"]}, "seeds": [1.5]}"#,
            r#"{"name": "p/q", "grid": {"model": ["cnn_t"]}}"#,       // bad dir name
            r#"{"name": "p", "base": {"steps": 1}, "grid": {"steps": [1]}}"#, // both
        ] {
            assert!(Plan::from_json(&plan_json(bad)).is_err(), "{bad}");
        }
    }

    #[test]
    fn unknown_grid_key_error_lists_registry() {
        let err = Plan::from_json(&plan_json(
            r#"{"name": "p", "grid": {"stepz": [1]}}"#,
        ))
        .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stepz"), "{msg}");
        for key in ["model", "cfg", "steps", "optimizer", "milestones"] {
            assert!(msg.contains(key), "listing must contain {key:?}: {msg}");
        }
    }

    #[test]
    fn expansion_is_deterministic_with_seeds_innermost() {
        let p = Plan::from_json(&plan_json(
            r#"{"name": "p", "base": {"steps": 2, "batch": 4},
                "grid": {"model": ["cnn_t"], "cfg": ["fp32", "e2m4_gnc_eg8mg1_sr"]},
                "seeds": [0, 1]}"#,
        ))
        .unwrap();
        let trials = p.trials().unwrap();
        let ids: Vec<&str> = trials.iter().map(|t| t.id.as_str()).collect();
        // axes sorted (cfg, model), last axis fastest, seeds innermost
        assert_eq!(
            ids,
            vec![
                "t000__cnn_t__fp32__s0",
                "t001__cnn_t__fp32__s1",
                "t002__cnn_t__e2m4_gnc_eg8mg1_sr__s0",
                "t003__cnn_t__e2m4_gnc_eg8mg1_sr__s1",
            ]
        );
        assert!(trials.iter().all(|t| t.config.steps == 2 && t.config.batch == 4));
        assert_eq!(trials[1].config.seed, 1);
        assert_eq!(p.trials().unwrap().len(), 4, "re-expansion is stable");
    }

    #[test]
    fn expansion_rejects_pjrt_and_bad_configs() {
        let pjrt = Plan::from_json(&plan_json(
            r#"{"name": "p", "base": {"backend": "pjrt"}, "grid": {"model": ["cnn_t"]}}"#,
        ))
        .unwrap();
        let msg = format!("{:#}", pjrt.trials().unwrap_err());
        assert!(msg.contains("native"), "{msg}");
        // a quant config the native backend cannot run fails at expansion
        let bad = Plan::from_json(&plan_json(
            r#"{"name": "p", "grid": {"cfg": ["e2m4_g1_eg8mg1_sr"]}}"#,
        ))
        .unwrap();
        assert!(bad.trials().is_err());
    }

    #[test]
    fn repeats_become_seed_range() {
        let p = Plan::from_json(&plan_json(
            r#"{"name": "p", "grid": {"model": ["cnn_t"]}, "repeats": 3}"#,
        ))
        .unwrap();
        assert_eq!(p.seeds, vec![0, 1, 2]);
    }

    #[test]
    fn validate_trial_output_rejects_mismatches() {
        let p = Plan::from_json(&plan_json(
            r#"{"name": "p", "base": {"steps": 2, "batch": 4}, "grid": {"model": ["cnn_t"]}}"#,
        ))
        .unwrap();
        let trials = p.trials().unwrap();
        let t = &trials[0];
        // a synthetic minimal valid output
        let mk = |cfg: Json| {
            let mut m = BTreeMap::new();
            m.insert("plan".to_string(), Json::Str("p".to_string()));
            m.insert("trial".to_string(), Json::Str(t.id.clone()));
            m.insert("index".to_string(), Json::Num(0.0));
            m.insert("seed".to_string(), Json::Num(0.0));
            m.insert("config".to_string(), cfg);
            let mut r = BTreeMap::new();
            r.insert("status".to_string(), Json::Str("ok".to_string()));
            r.insert("steps_run".to_string(), Json::Num(2.0));
            r.insert("final_loss".to_string(), Json::Num(1.0));
            r.insert("test_loss".to_string(), Json::Num(1.0));
            r.insert("test_acc".to_string(), Json::Num(0.5));
            r.insert("loss_curve".to_string(), Json::Arr(vec![]));
            r.insert("acc_curve".to_string(), Json::Arr(vec![]));
            r.insert("eval".to_string(), Json::Arr(vec![]));
            r.insert("audit_steps".to_string(), Json::Num(0.0));
            r.insert("state_checksum".to_string(), Json::Str("00".to_string()));
            m.insert("result".to_string(), Json::Obj(r));
            let mut tm = BTreeMap::new();
            tm.insert("mean_step_ms".to_string(), Json::Num(1.0));
            tm.insert("total_ms".to_string(), Json::Num(2.0));
            tm.insert("steps_executed".to_string(), Json::Num(2.0));
            m.insert("timing".to_string(), Json::Obj(tm));
            Json::Obj(m)
        };
        validate_trial_output(&mk(t.config.to_json()), &p, t).unwrap();
        // a config echo that differs (stale plan) must invalidate
        let mut other = t.config.clone();
        other.steps = 99;
        assert!(validate_trial_output(&mk(other.to_json()), &p, t).is_err());
        // a missing result key must invalidate
        let mut v = mk(t.config.to_json());
        if let Json::Obj(m) = &mut v {
            if let Some(Json::Obj(r)) = m.get_mut("result") {
                r.remove("state_checksum");
            }
        }
        assert!(validate_trial_output(&v, &p, t).is_err());
        // pre-fault-tolerance outputs (no timing.steps_executed) re-run
        let mut v = mk(t.config.to_json());
        if let Json::Obj(m) = &mut v {
            if let Some(Json::Obj(tm)) = m.get_mut("timing") {
                tm.remove("steps_executed");
            }
        }
        assert!(validate_trial_output(&v, &p, t).is_err());
    }
}
