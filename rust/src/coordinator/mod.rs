//! L3 coordinator: the training-run driver and the experiment harness.
//!
//! The paper's contribution is the numeric format (L1/L2), so the
//! coordinator is a thin-driver-plus-substrates: a config system, the
//! training loop over a selectable backend (the self-contained native
//! Alg. 1 trainer by default, the PJRT engine with `backend=pjrt`),
//! metrics/checkpointing, and the registry that maps every paper
//! table/figure to a runnable experiment. [`checkpoint`] holds the
//! step-checkpoint codec behind the trainer's crash-safe,
//! bit-identical resume.

pub mod checkpoint;
pub mod config;
pub mod experiments;
pub mod lab;
pub mod metrics;
pub mod trainer;

pub use checkpoint::{Checkpoint, CheckpointIo};
pub use config::{Backend, TrainConfig};
pub use lab::{LabReport, Plan};
pub use trainer::{train, train_native, validate_native_config, TrainResult};
