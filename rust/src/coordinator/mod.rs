//! L3 coordinator: the training-run driver and the experiment harness.
//!
//! The paper's contribution is the numeric format (L1/L2), so the
//! coordinator is a thin-driver-plus-substrates: a config system, the
//! training loop over the PJRT engine, metrics/checkpointing, and the
//! registry that maps every paper table/figure to a runnable experiment.

pub mod config;
pub mod experiments;
pub mod metrics;
pub mod trainer;

pub use config::TrainConfig;
pub use trainer::{train, TrainResult};
