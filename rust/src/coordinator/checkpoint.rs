//! Step checkpoints: the full mid-run training state, written atomically
//! and durably every `checkpoint_every` steps, resumable bit-identically.
//!
//! A [`Checkpoint`] carries EVERYTHING the native trainer's step loop
//! depends on: the flat parameter vector, the optimizer slots (momentum
//! velocity), the next step index, the health-policy state (lr scale,
//! rollback count, monitor best-loss/streak) and the run accumulators
//! the final `TrainResult` is built from (metrics rows, eval rows, audit
//! totals). Two state sources are deliberately NOT serialized because
//! they are pure functions of `(config, step)` and reconstruct exactly:
//! the per-step stochastic-rounding RNG (re-seeded fresh each step from
//! `step_seed`) and the data order (`train_batch_index`); and BN layers
//! carry no running statistics (batch stats + learnable gamma/beta, the
//! latter in the parameter vector).
//!
//! On disk (all little-endian): an 8-byte magic, the fields, a
//! length-prefixed echo of the exact `TrainConfig::to_json` string the
//! run was launched with, and an FNV-1a-64 trailer over every preceding
//! byte ([`crate::nn::train::Fnv1a`]). The loader rejects anything with
//! a wrong magic, bad trailer, short buffer or mismatched config echo —
//! a checkpoint from a different config must never silently seed a
//! "resumed" run. [`CheckpointIo`] rotates `<tag>.ckpt.bin` to
//! `<tag>.ckpt.prev.bin` before each save (so one corrupted latest file
//! still leaves a good anchor) and mirrors the integrity metadata into a
//! human/CI-readable `<tag>.ckpt.json` manifest
//! (`schemas/checkpoint_manifest.schema.json`).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, ensure, Context, Result};

use super::metrics::{EvalRow, StepRow};
use crate::nn::train::{Fnv1a, StepAudit};
use crate::nn::PassCounters;
use crate::util::fsio;
use crate::util::json::Json;

const MAGIC: &[u8; 8] = b"MLSCKPT1";

/// Full mid-run trainer state at a step boundary: everything needed to
/// continue bit-identically from `next_step`.
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    /// the first step the resumed run executes
    pub next_step: u64,
    /// flat parameter vector (`NativeModel::state`)
    pub state: Vec<f32>,
    /// name of the optimizer that produced `opt_state`
    pub opt_name: String,
    /// flat optimizer slots (`Optimizer::state`; empty for sgd)
    pub opt_state: Vec<f32>,
    /// learning-rate scale accumulated by `halve_lr` recoveries
    pub lr_scale: f32,
    /// rollback recoveries so far (bounded by `health::MAX_ROLLBACKS`)
    pub rollbacks: u64,
    /// health-monitor best-loss (f32::INFINITY before the first step)
    pub health_best_loss: f32,
    /// health-monitor blow-up streak
    pub health_streak: u64,
    /// metrics rows of steps 0..next_step
    pub steps: Vec<StepRow>,
    /// eval rows recorded so far
    pub evals: Vec<EvalRow>,
    /// number of steps folded into `audit_totals`
    pub audit_steps: u64,
    /// audit roll-up so far (`layers` is always empty here — the
    /// per-step stream lives in `<tag>.audit.jsonl`)
    pub audit_totals: StepAudit,
    /// exact `TrainConfig::to_json().to_string_compact()` of the run
    pub config_echo: String,
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    push_u32(out, v.to_bits());
}

fn push_f64(out: &mut Vec<u8>, v: f64) {
    push_u64(out, v.to_bits());
}

fn push_bytes(out: &mut Vec<u8>, b: &[u8]) {
    push_u64(out, b.len() as u64);
    out.extend_from_slice(b);
}

fn push_f32s(out: &mut Vec<u8>, vs: &[f32]) {
    push_u64(out, vs.len() as u64);
    for v in vs {
        push_f32(out, *v);
    }
}

fn push_pass(out: &mut Vec<u8>, p: &PassCounters) {
    push_u64(out, p.convs);
    push_u64(out, p.mul_ops);
    push_u64(out, p.int_add_ops);
    push_u64(out, p.float_add_ops);
    push_u64(out, p.group_scale_ops);
    push_u32(out, p.peak_acc_bits);
}

/// Bounds-checked little-endian cursor for [`Checkpoint::decode`].
struct Reader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            n <= self.b.len() - self.pos,
            "checkpoint truncated: need {n} bytes at offset {}, have {}",
            self.pos,
            self.b.len() - self.pos
        );
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length prefix for `elem` - byte elements, sanity - bounded by the
    /// remaining buffer so a corrupt length cannot drive a huge alloc.
    fn len(&mut self, elem: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        ensure!(
            n.checked_mul(elem).is_some_and(|total| total <= self.b.len() - self.pos),
            "checkpoint corrupt: length {n} x {elem}B exceeds remaining {}B",
            self.b.len() - self.pos
        );
        Ok(n)
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.len(4)?;
        (0..n).map(|_| self.f32()).collect()
    }

    fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.len(1)?;
        self.take(n)
    }

    fn pass(&mut self) -> Result<PassCounters> {
        Ok(PassCounters {
            convs: self.u64()?,
            mul_ops: self.u64()?,
            int_add_ops: self.u64()?,
            float_add_ops: self.u64()?,
            group_scale_ops: self.u64()?,
            peak_acc_bits: self.u32()?,
        })
    }
}

impl Checkpoint {
    /// Serialize to the on-disk byte format (FNV-1a trailer included).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        push_u64(&mut out, self.next_step);
        push_f32(&mut out, self.lr_scale);
        push_u64(&mut out, self.rollbacks);
        push_f32(&mut out, self.health_best_loss);
        push_u64(&mut out, self.health_streak);
        push_bytes(&mut out, self.opt_name.as_bytes());
        push_f32s(&mut out, &self.state);
        push_f32s(&mut out, &self.opt_state);
        push_u64(&mut out, self.steps.len() as u64);
        for r in &self.steps {
            push_u64(&mut out, r.step);
            push_f32(&mut out, r.lr);
            push_f32(&mut out, r.loss);
            push_f32(&mut out, r.acc);
            push_f64(&mut out, r.step_ms);
        }
        push_u64(&mut out, self.evals.len() as u64);
        for r in &self.evals {
            push_u64(&mut out, r.step);
            push_f32(&mut out, r.loss);
            push_f32(&mut out, r.acc);
        }
        push_u64(&mut out, self.audit_steps);
        push_pass(&mut out, &self.audit_totals.forward);
        push_pass(&mut out, &self.audit_totals.wgrad);
        push_pass(&mut out, &self.audit_totals.dgrad);
        push_bytes(&mut out, self.config_echo.as_bytes());
        let trailer = fnv1a_trailer(&out);
        push_u64(&mut out, trailer);
        out
    }

    /// Decode and verify a byte buffer written by [`Self::encode`].
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        ensure!(bytes.len() >= MAGIC.len() + 8, "checkpoint truncated: {} bytes", bytes.len());
        let (body, trailer) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(trailer.try_into().expect("8 bytes"));
        let computed = fnv1a_trailer(body);
        ensure!(
            stored == computed,
            "checkpoint checksum mismatch: stored {stored:016x}, computed {computed:016x}"
        );
        let mut r = Reader { b: body, pos: 0 };
        let magic = r.take(MAGIC.len())?;
        ensure!(magic == MAGIC, "bad checkpoint magic {magic:?}");
        let next_step = r.u64()?;
        let lr_scale = r.f32()?;
        let rollbacks = r.u64()?;
        let health_best_loss = r.f32()?;
        let health_streak = r.u64()?;
        let opt_name = String::from_utf8(r.bytes()?.to_vec())
            .map_err(|e| anyhow!("checkpoint optimizer name is not UTF-8: {e}"))?;
        let state = r.f32s()?;
        let opt_state = r.f32s()?;
        let n_steps = r.len(8 + 4 + 4 + 4 + 8)?;
        let steps = (0..n_steps)
            .map(|_| {
                Ok(StepRow {
                    step: r.u64()?,
                    lr: r.f32()?,
                    loss: r.f32()?,
                    acc: r.f32()?,
                    step_ms: r.f64()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let n_evals = r.len(8 + 4 + 4)?;
        let evals = (0..n_evals)
            .map(|_| Ok(EvalRow { step: r.u64()?, loss: r.f32()?, acc: r.f32()? }))
            .collect::<Result<Vec<_>>>()?;
        let audit_steps = r.u64()?;
        let audit_totals = StepAudit {
            forward: r.pass()?,
            wgrad: r.pass()?,
            dgrad: r.pass()?,
            layers: Vec::new(),
        };
        let config_echo = String::from_utf8(r.bytes()?.to_vec())
            .map_err(|e| anyhow!("checkpoint config echo is not UTF-8: {e}"))?;
        ensure!(r.pos == body.len(), "checkpoint has {} trailing bytes", body.len() - r.pos);
        Ok(Checkpoint {
            next_step,
            state,
            opt_name,
            opt_state,
            lr_scale,
            rollbacks,
            health_best_loss,
            health_streak,
            steps,
            evals,
            audit_steps,
            audit_totals,
            config_echo,
        })
    }

    /// Read and verify a checkpoint at an explicit path (the serve
    /// loader). Unlike [`CheckpointIo::load_for_resume`] this imposes no
    /// config-echo equality — an inference config legitimately differs
    /// from the training config that wrote the file, so the caller
    /// decides which echo fields matter (model, cfg, seed).
    pub fn load_file(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path).with_context(|| format!("read checkpoint {path:?}"))?;
        Checkpoint::decode(&bytes).with_context(|| format!("decode checkpoint {path:?}"))
    }
}

/// The FNV-1a-64 integrity trailer over a checkpoint body.
pub fn fnv1a_trailer(body: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.update(body);
    h.finish()
}

/// File layout + rotation for one run's checkpoints:
/// `<dir>/<tag>.ckpt.bin` (latest), `<dir>/<tag>.ckpt.prev.bin`
/// (previous good, the corruption fallback) and `<dir>/<tag>.ckpt.json`
/// (the manifest mirroring the latest file's integrity metadata).
pub struct CheckpointIo {
    dir: PathBuf,
    tag: String,
}

impl CheckpointIo {
    pub fn new(dir: &Path, tag: &str) -> CheckpointIo {
        CheckpointIo { dir: dir.to_path_buf(), tag: tag.to_string() }
    }

    pub fn latest_path(&self) -> PathBuf {
        self.dir.join(format!("{}.ckpt.bin", self.tag))
    }

    pub fn prev_path(&self) -> PathBuf {
        self.dir.join(format!("{}.ckpt.prev.bin", self.tag))
    }

    pub fn manifest_path(&self) -> PathBuf {
        self.dir.join(format!("{}.ckpt.json", self.tag))
    }

    /// Rotate latest -> prev, then durably write the new latest plus its
    /// manifest.
    pub fn save(&self, ckpt: &Checkpoint) -> Result<()> {
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("checkpoint dir {:?}", self.dir))?;
        let latest = self.latest_path();
        if latest.exists() {
            std::fs::rename(&latest, self.prev_path())
                .with_context(|| format!("rotate {latest:?}"))?;
            fsio::sync_parent_dir(&latest)?;
        }
        let bytes = ckpt.encode();
        let trailer = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().expect("8 bytes"));
        fsio::write_atomic(&latest, &bytes)?;
        let manifest = self.manifest_json(ckpt, &bytes, trailer);
        fsio::write_atomic(&self.manifest_path(), manifest.to_string_pretty().as_bytes())?;
        Ok(())
    }

    fn manifest_json(&self, ckpt: &Checkpoint, bytes: &[u8], trailer: u64) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("format".to_string(), Json::Str("MLSCKPT1".to_string()));
        m.insert("tag".to_string(), Json::Str(self.tag.clone()));
        m.insert(
            "file".to_string(),
            Json::Str(format!("{}.ckpt.bin", self.tag)),
        );
        m.insert("bytes".to_string(), Json::Num(bytes.len() as f64));
        m.insert("checksum_fnv1a".to_string(), Json::Str(format!("{trailer:016x}")));
        m.insert("next_step".to_string(), Json::Num(ckpt.next_step as f64));
        m.insert("state_len".to_string(), Json::Num(ckpt.state.len() as f64));
        m.insert("optimizer".to_string(), Json::Str(ckpt.opt_name.clone()));
        m.insert("opt_slots".to_string(), Json::Num(ckpt.opt_state.len() as f64));
        m.insert("lr_scale".to_string(), Json::Num(ckpt.lr_scale as f64));
        m.insert("rollbacks".to_string(), Json::Num(ckpt.rollbacks as f64));
        m.insert("steps_recorded".to_string(), Json::Num(ckpt.steps.len() as f64));
        m.insert("evals_recorded".to_string(), Json::Num(ckpt.evals.len() as f64));
        m.insert("audit_steps".to_string(), Json::Num(ckpt.audit_steps as f64));
        Json::Obj(m)
    }

    /// Load the newest valid checkpoint matching `config_echo`: the
    /// latest file first, then — with a warning — the rotated previous
    /// one (the corrupt-latest recovery path). `None` when neither file
    /// exists or validates; a checkpoint whose config echo differs is
    /// treated as invalid (a stale run's state must not leak in).
    pub fn load_for_resume(&self, config_echo: &str) -> Option<Checkpoint> {
        for (path, is_prev) in [(self.latest_path(), false), (self.prev_path(), true)] {
            let Ok(bytes) = std::fs::read(&path) else { continue };
            match Checkpoint::decode(&bytes) {
                Ok(ckpt) if ckpt.config_echo == config_echo => {
                    if is_prev {
                        eprintln!(
                            "[checkpoint] {:?} invalid, resuming from previous good {path:?} \
                             (step {})",
                            self.latest_path(),
                            ckpt.next_step
                        );
                    }
                    return Some(ckpt);
                }
                Ok(_) => {
                    eprintln!("[checkpoint] {path:?} is from a different config — ignoring");
                }
                Err(e) => {
                    eprintln!("[checkpoint] {path:?} failed validation: {e:#}");
                }
            }
        }
        None
    }

    /// Delete every checkpoint artifact of this run (the lab's
    /// `--force` path: a forced re-run must start from step 0).
    pub fn remove_all(&self) -> Result<()> {
        for p in [self.latest_path(), self.prev_path(), self.manifest_path()] {
            if p.exists() {
                std::fs::remove_file(&p).with_context(|| format!("remove {p:?}"))?;
            }
        }
        Ok(())
    }

    /// Flip one byte in the middle of the latest checkpoint file — the
    /// `corrupt_ckpt` fault site (simulated disk damage, deliberately a
    /// plain in-place write).
    pub fn corrupt_latest(&self) -> Result<()> {
        let path = self.latest_path();
        let mut bytes = std::fs::read(&path).with_context(|| format!("corrupt {path:?}"))?;
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, bytes)?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            next_step: 7,
            state: vec![0.5, -1.25, f32::MIN_POSITIVE, -0.0, 3.5e-39],
            opt_name: "momentum".to_string(),
            opt_state: vec![0.125, -2.0],
            lr_scale: 0.25,
            rollbacks: 2,
            health_best_loss: 1.375,
            health_streak: 1,
            steps: vec![
                StepRow { step: 5, lr: 0.05, loss: 2.0, acc: 0.25, step_ms: 12.5 },
                StepRow { step: 6, lr: 0.05, loss: f32::NAN, acc: 0.5, step_ms: 13.0 },
            ],
            evals: vec![EvalRow { step: 5, loss: 1.9, acc: 0.3 }],
            audit_steps: 6,
            audit_totals: StepAudit {
                forward: PassCounters { convs: 3, mul_ops: 100, peak_acc_bits: 17, ..Default::default() },
                wgrad: PassCounters { convs: 3, int_add_ops: 90, ..Default::default() },
                dgrad: PassCounters { convs: 3, group_scale_ops: 12, ..Default::default() },
                layers: Vec::new(),
            },
            config_echo: r#"{"batch":"4","model":"cnn_t"}"#.to_string(),
        }
    }

    /// `PartialEq` on f32 treats NaN != NaN; compare through the encoded
    /// bytes, which are exact.
    fn assert_bit_identical(a: &Checkpoint, b: &Checkpoint) {
        assert_eq!(a.encode(), b.encode());
    }

    #[test]
    fn codec_round_trips_bit_exactly() {
        let ckpt = sample();
        let bytes = ckpt.encode();
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_bit_identical(&ckpt, &back);
        assert_eq!(back.next_step, 7);
        assert_eq!(back.opt_name, "momentum");
        assert!(back.steps[1].loss.is_nan(), "NaN rows must survive the trip");
        assert_eq!(back.steps[1].loss.to_bits(), ckpt.steps[1].loss.to_bits());
        assert!(back.audit_totals.layers.is_empty());
        // empty-vec edge: a fresh sgd run right after step 0
        let empty = Checkpoint {
            state: Vec::new(),
            opt_state: Vec::new(),
            steps: Vec::new(),
            evals: Vec::new(),
            opt_name: "sgd".to_string(),
            ..sample()
        };
        assert_bit_identical(&empty, &Checkpoint::decode(&empty.encode()).unwrap());
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        let bytes = sample().encode();
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x01;
            assert!(
                Checkpoint::decode(&bad).is_err(),
                "flip at byte {i}/{} must fail the checksum",
                bytes.len()
            );
        }
    }

    #[test]
    fn truncation_and_garbage_are_rejected() {
        let bytes = sample().encode();
        for cut in [0, 1, 8, 15, bytes.len() / 2, bytes.len() - 1] {
            assert!(Checkpoint::decode(&bytes[..cut]).is_err(), "cut at {cut}");
        }
        assert!(Checkpoint::decode(&[0u8; 64]).is_err());
        // valid trailer over a wrong-magic body must still fail
        let mut body = bytes[..bytes.len() - 8].to_vec();
        body[0] ^= 0xff;
        let t = fnv1a_trailer(&body);
        body.extend_from_slice(&t.to_le_bytes());
        let err = format!("{:#}", Checkpoint::decode(&body).unwrap_err());
        assert!(err.contains("magic"), "{err}");
    }

    #[test]
    fn io_rotates_and_falls_back_on_corruption() {
        let dir = std::env::temp_dir().join("mls_ckpt_test").join("rotate");
        let _ = std::fs::remove_dir_all(&dir);
        let io = CheckpointIo::new(&dir, "cnn_t_fp32_s0");
        let echo = sample().config_echo.clone();
        assert!(io.load_for_resume(&echo).is_none(), "no files yet");

        let first = Checkpoint { next_step: 4, ..sample() };
        io.save(&first).unwrap();
        assert_eq!(io.load_for_resume(&echo).unwrap().next_step, 4);
        assert!(!io.prev_path().exists(), "first save has nothing to rotate");

        let second = Checkpoint { next_step: 6, ..sample() };
        io.save(&second).unwrap();
        assert_eq!(io.load_for_resume(&echo).unwrap().next_step, 6);
        assert_eq!(Checkpoint::decode(&std::fs::read(io.prev_path()).unwrap()).unwrap().next_step, 4);

        // corrupt the latest: resume falls back to the rotated previous
        io.corrupt_latest().unwrap();
        let recovered = io.load_for_resume(&echo).unwrap();
        assert_eq!(recovered.next_step, 4, "must fall back to the previous good checkpoint");

        // a different config echo must refuse both files
        assert!(io.load_for_resume("{\"other\":\"config\"}").is_none());

        // manifest mirrors the latest save
        let manifest = Json::parse(&std::fs::read_to_string(io.manifest_path()).unwrap()).unwrap();
        assert_eq!(manifest.get("next_step").and_then(|v| v.as_f64()), Some(6.0));
        assert_eq!(
            manifest.get("optimizer").and_then(|v| v.as_str()),
            Some("momentum")
        );

        io.remove_all().unwrap();
        assert!(io.load_for_resume(&echo).is_none());
        assert!(!io.manifest_path().exists());
    }

    #[test]
    fn load_file_verifies_but_skips_the_echo_check() {
        let dir = std::env::temp_dir().join("mls_ckpt_test").join("load_file");
        let _ = std::fs::remove_dir_all(&dir);
        let io = CheckpointIo::new(&dir, "cnn_t_fp32_s0");
        let ckpt = sample();
        io.save(&ckpt).unwrap();
        // explicit-path load succeeds regardless of who asks (no echo)
        let back = Checkpoint::load_file(&io.latest_path()).unwrap();
        assert_bit_identical(&ckpt, &back);
        // ... but integrity is still enforced
        io.corrupt_latest().unwrap();
        let err = format!("{:#}", Checkpoint::load_file(&io.latest_path()).unwrap_err());
        assert!(err.contains("checksum"), "{err}");
        assert!(Checkpoint::load_file(&dir.join("missing.ckpt.bin")).is_err());
    }
}
