//! Run configuration: one TYPED KEY REGISTRY shared by every surface.
//!
//! Every config key is declared exactly once in [`CONFIG_KEYS`] — name,
//! docstring, default, renderer and parser — and everything else derives
//! from that single declaration:
//!
//! * `--set key=value` on the CLI ([`TrainConfig::set`]) and key=value
//!   config files ([`TrainConfig::load_file`]),
//! * the per-subcommand `--help` key table ([`help_table`]),
//! * the JSON round trip ([`TrainConfig::to_json`] /
//!   [`TrainConfig::from_json`]) that the lab runner
//!   ([`crate::coordinator::lab`]) uses for plan expansion,
//!   `trial_input.json` and crash-resume validation,
//! * the unknown-key error, which lists every valid key with its default
//!   and docstring (so a typo is self-diagnosing).
//!
//! Enum-valued keys delegate to their own name registries
//! ([`Backend::ALL`], [`crate::mls::Grouping::ALL`],
//! [`crate::mls::Rounding::ALL`], [`crate::nn::optim::OPTIMIZERS`]), each
//! of which parses by scanning the same array its `name()` reads from —
//! the supported-name listings cannot drift from what parses.

use anyhow::{anyhow, Result};

use crate::data::DatasetConfig;
use crate::util::json::Json;

/// Learning-rate schedule: the paper's step decay (x0.1 at milestones).
#[derive(Clone, Debug, PartialEq)]
pub struct LrSchedule {
    pub base: f32,
    /// steps (not epochs — we are step-based) at which lr decays by 10
    pub milestones: Vec<u64>,
}

impl LrSchedule {
    pub fn at(&self, step: u64) -> f32 {
        let decays = self.milestones.iter().filter(|&&m| step >= m).count();
        self.base * 0.1f32.powi(decays as i32)
    }
}

/// Which execution backend runs the train/eval steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The in-crate Alg. 1 trainer ([`crate::nn::train`]): quantized
    /// forward/backward convs on the pass-generic packed-GEMM engine,
    /// zero external dependencies. The default.
    Native,
    /// The PJRT engine over AOT artifacts (needs `make artifacts` and the
    /// `pjrt` cargo feature; the stub errors otherwise).
    Pjrt,
}

impl Backend {
    /// Every supported backend; [`Self::parse`] scans this list so the
    /// parseable set cannot drift from the `name()` outputs.
    pub const ALL: [Backend; 2] = [Backend::Native, Backend::Pjrt];

    pub fn parse(s: &str) -> Result<Backend> {
        Self::ALL.into_iter().find(|b| b.name() == s).ok_or_else(|| {
            anyhow!("unknown backend {s:?} (have {:?})", Self::ALL.map(|b| b.name()))
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// One training run.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub model: String,
    /// quant config name as in the manifest (e.g. "e2m4_gnc_eg8mg1_sr", "fp32")
    pub cfg_name: String,
    pub backend: Backend,
    pub steps: u64,
    /// batch size of the native backend (the PJRT artifacts bake their
    /// own batch into the manifest)
    pub batch: usize,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub lr: LrSchedule,
    /// native-backend parameter-update rule: "sgd" (plain) or "momentum"
    pub optimizer: String,
    /// momentum coefficient (used when `optimizer=momentum`)
    pub momentum: f32,
    /// L2 weight decay folded into the gradient (0 = off)
    pub weight_decay: f32,
    pub seed: u64,
    pub data: DatasetConfig,
    /// write a resumable step checkpoint every N steps (0 = off; needs
    /// `out_dir`)
    pub checkpoint_every: u64,
    /// resume from a valid `<tag>.ckpt.bin` in `out_dir` when present
    pub resume: bool,
    /// numeric-health recovery policy: "abort" | "rollback" | "halve_lr"
    /// ([`crate::nn::health::POLICIES`])
    pub on_divergence: String,
    /// consecutive loss-blow-up steps before `on_divergence` fires
    /// (0 = NaN/Inf + scale-saturation guards only)
    pub divergence_window: u64,
    /// a step counts as a blow-up when loss > factor x best-so-far
    pub divergence_factor: f32,
    /// where to write metrics CSV / checkpoints / the per-layer audit
    /// stream (None = no files)
    pub out_dir: Option<String>,
    /// serve: max requests coalesced into one forward batch (>= 1)
    pub serve_batch_max: usize,
    /// serve: microseconds an open batch waits for more requests before
    /// dispatch (0 = dispatch whatever is pending immediately)
    pub serve_batch_wait_us: u64,
    /// serve transport: "jsonl" (length-prefixed frames on stdin/stdout)
    /// or "tcp" ([`std::net::TcpListener`], same framing per connection)
    pub serve_mode: String,
    /// serve: TCP listen port for `serve_mode=tcp` (0 = OS-assigned,
    /// printed on startup)
    pub serve_port: u16,
    /// deterministic fault-injection spec
    /// (`<site>@step<k>[:seed]`, [`crate::util::fault::FaultSpec`]).
    /// NOT a registry key: it never round-trips through
    /// `to_json`/`trial_input.json`, so a crashed faulted run and its
    /// clean resume share one config echo. Tests set it directly; the
    /// CLI path picks it up from `MLS_FAULT`.
    pub fault: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "cnn_s".to_string(),
            cfg_name: "e2m4_gnc_eg8mg1_sr".to_string(),
            backend: Backend::Native,
            steps: 300,
            batch: 32,
            eval_every: 50,
            eval_batches: 16,
            lr: LrSchedule { base: 0.05, milestones: vec![150, 250] },
            optimizer: "sgd".to_string(),
            momentum: 0.9,
            weight_decay: 0.0,
            seed: 0,
            data: DatasetConfig::default(),
            checkpoint_every: 0,
            resume: true,
            on_divergence: "abort".to_string(),
            divergence_window: 0,
            divergence_factor: 10.0,
            out_dir: None,
            serve_batch_max: 8,
            serve_batch_wait_us: 200,
            serve_mode: "jsonl".to_string(),
            serve_port: 0,
            fault: None,
        }
    }
}

/// One config key: its name, docstring, default, renderer and parser.
/// The registry ([`CONFIG_KEYS`]) is the ONLY place a key is declared;
/// `set`/`get`/`to_json`/`from_json`/`help_table` all iterate it.
pub struct KeySpec {
    pub key: &'static str,
    /// one-line help text (what the key does + accepted values)
    pub doc: &'static str,
    /// render the default value (what `--help` shows)
    pub default: fn() -> String,
    /// render the current value (what `to_json` writes)
    pub get: fn(&TrainConfig) -> String,
    /// parse and apply one value (what `--set`/`from_json` call)
    pub set: fn(&mut TrainConfig, &str) -> Result<()>,
}

/// Accepted spellings that map onto a registry key (kept for CLI
/// back-compat; the canonical key is what `to_json` emits).
pub const KEY_ALIASES: &[(&str, &str)] = &[("cfg_name", "cfg")];

/// The typed config key registry — every [`TrainConfig`] key, declared
/// once. Order is the `--help` / `to_json` display order.
pub static CONFIG_KEYS: &[KeySpec] = &[
    KeySpec {
        key: "model",
        doc: "model to train (native: cnn_t | cnn_s | resnet_t; pjrt: manifest models)",
        default: || TrainConfig::default().model,
        get: |c| c.model.clone(),
        set: |c, v| {
            c.model = v.to_string();
            Ok(())
        },
    },
    KeySpec {
        key: "cfg",
        doc: "quant config name in QuantConfig::name() form (e.g. e2m4_gnc_eg8mg1_sr) or fp32",
        default: || TrainConfig::default().cfg_name,
        get: |c| c.cfg_name.clone(),
        set: |c, v| {
            // every accepted name must parse as a quantizer config (the
            // manifest names use the same scheme), so typos fail here
            // with the registry listing instead of mid-run
            crate::mls::quantizer::QuantConfig::parse_name(v)?;
            c.cfg_name = v.to_string();
            Ok(())
        },
    },
    KeySpec {
        key: "backend",
        doc: "execution backend: native | pjrt",
        default: || TrainConfig::default().backend.name().to_string(),
        get: |c| c.backend.name().to_string(),
        set: |c, v| {
            c.backend = Backend::parse(v)?;
            Ok(())
        },
    },
    KeySpec {
        key: "steps",
        doc: "number of training steps",
        default: || TrainConfig::default().steps.to_string(),
        get: |c| c.steps.to_string(),
        set: |c, v| {
            c.steps = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "batch",
        doc: "native-backend batch size (pjrt batch is baked into the artifact)",
        default: || TrainConfig::default().batch.to_string(),
        get: |c| c.batch.to_string(),
        set: |c, v| {
            c.batch = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "eval_every",
        doc: "run a validation eval every N steps (0 = never)",
        default: || TrainConfig::default().eval_every.to_string(),
        get: |c| c.eval_every.to_string(),
        set: |c, v| {
            c.eval_every = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "eval_batches",
        doc: "batches per validation/test eval",
        default: || TrainConfig::default().eval_batches.to_string(),
        get: |c| c.eval_batches.to_string(),
        set: |c, v| {
            c.eval_batches = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "lr",
        doc: "base learning rate of the step-decay schedule",
        default: || TrainConfig::default().lr.base.to_string(),
        get: |c| c.lr.base.to_string(),
        set: |c, v| {
            c.lr.base = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "milestones",
        doc: "comma-separated steps at which lr decays x0.1 (empty = no decay)",
        default: || render_milestones(&TrainConfig::default().lr.milestones),
        get: |c| render_milestones(&c.lr.milestones),
        set: |c, v| {
            c.lr.milestones = v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.parse().map_err(|e| anyhow!("milestone {s:?}: {e}")))
                .collect::<Result<Vec<u64>>>()?;
            Ok(())
        },
    },
    KeySpec {
        key: "optimizer",
        doc: "native-backend parameter-update rule: sgd | momentum",
        default: || TrainConfig::default().optimizer,
        get: |c| c.optimizer.clone(),
        set: |c, v| {
            anyhow::ensure!(
                crate::nn::optim::OPTIMIZERS.contains(&v),
                "unknown optimizer {v:?} (have {:?})",
                crate::nn::optim::OPTIMIZERS
            );
            c.optimizer = v.to_string();
            Ok(())
        },
    },
    KeySpec {
        key: "momentum",
        doc: "momentum coefficient (used when optimizer=momentum)",
        default: || TrainConfig::default().momentum.to_string(),
        get: |c| c.momentum.to_string(),
        set: |c, v| {
            c.momentum = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "weight_decay",
        doc: "L2 weight decay folded into the gradient (0 = off)",
        default: || TrainConfig::default().weight_decay.to_string(),
        get: |c| c.weight_decay.to_string(),
        set: |c, v| {
            c.weight_decay = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "seed",
        doc: "run seed: parameter init, data order and stochastic rounding",
        default: || TrainConfig::default().seed.to_string(),
        get: |c| c.seed.to_string(),
        set: |c, v| {
            c.seed = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "noise",
        doc: "synthetic-dataset additive noise sigma (task difficulty)",
        default: || TrainConfig::default().data.noise.to_string(),
        get: |c| c.data.noise.to_string(),
        set: |c, v| {
            c.data.noise = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "label_noise",
        doc: "synthetic-dataset wrong-label probability (error floor)",
        default: || TrainConfig::default().data.label_noise.to_string(),
        get: |c| c.data.label_noise.to_string(),
        set: |c, v| {
            c.data.label_noise = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "data_seed",
        doc: "synthetic-dataset template seed (class templates + batches)",
        default: || TrainConfig::default().data.seed.to_string(),
        get: |c| c.data.seed.to_string(),
        set: |c, v| {
            c.data.seed = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "checkpoint_every",
        doc: "write a resumable step checkpoint every N steps (0 = off; needs out_dir)",
        default: || TrainConfig::default().checkpoint_every.to_string(),
        get: |c| c.checkpoint_every.to_string(),
        set: |c, v| {
            c.checkpoint_every = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "resume",
        doc: "resume from a valid <tag>.ckpt.bin in out_dir when present: true | false",
        default: || TrainConfig::default().resume.to_string(),
        get: |c| c.resume.to_string(),
        set: |c, v| {
            c.resume = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "on_divergence",
        doc: "numeric-health recovery policy: abort | rollback | halve_lr",
        default: || TrainConfig::default().on_divergence,
        get: |c| c.on_divergence.clone(),
        set: |c, v| {
            crate::nn::health::DivergencePolicy::parse(v)?;
            c.on_divergence = v.to_string();
            Ok(())
        },
    },
    KeySpec {
        key: "divergence_window",
        doc: "consecutive loss-blow-up steps before on_divergence fires (0 = NaN/Inf guards only)",
        default: || TrainConfig::default().divergence_window.to_string(),
        get: |c| c.divergence_window.to_string(),
        set: |c, v| {
            c.divergence_window = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "divergence_factor",
        doc: "a step counts as a loss blow-up when loss > factor x best-so-far (must be > 1)",
        default: || TrainConfig::default().divergence_factor.to_string(),
        get: |c| c.divergence_factor.to_string(),
        set: |c, v| {
            let f: f32 = v.parse()?;
            anyhow::ensure!(
                f.is_finite() && f > 1.0,
                "divergence_factor must be a finite value > 1, got {f}"
            );
            c.divergence_factor = f;
            Ok(())
        },
    },
    KeySpec {
        key: "out_dir",
        doc: "metrics CSV / checkpoint / audit-stream output directory (empty = no files)",
        default: || TrainConfig::default().out_dir.unwrap_or_default(),
        get: |c| c.out_dir.clone().unwrap_or_default(),
        set: |c, v| {
            c.out_dir = if v.is_empty() { None } else { Some(v.to_string()) };
            Ok(())
        },
    },
    KeySpec {
        key: "serve_batch_max",
        doc: "serve: max requests coalesced into one forward batch (>= 1)",
        default: || TrainConfig::default().serve_batch_max.to_string(),
        get: |c| c.serve_batch_max.to_string(),
        set: |c, v| {
            let n: usize = v.parse()?;
            anyhow::ensure!(n >= 1, "serve_batch_max must be >= 1, got {n}");
            c.serve_batch_max = n;
            Ok(())
        },
    },
    KeySpec {
        key: "serve_batch_wait_us",
        doc: "serve: microseconds an open batch waits for more requests (0 = dispatch immediately)",
        default: || TrainConfig::default().serve_batch_wait_us.to_string(),
        get: |c| c.serve_batch_wait_us.to_string(),
        set: |c, v| {
            c.serve_batch_wait_us = v.parse()?;
            Ok(())
        },
    },
    KeySpec {
        key: "serve_mode",
        doc: "serve transport: jsonl (length-prefixed frames on stdin/stdout) | tcp",
        default: || TrainConfig::default().serve_mode,
        get: |c| c.serve_mode.clone(),
        set: |c, v| {
            anyhow::ensure!(
                v == "jsonl" || v == "tcp",
                "unknown serve_mode {v:?} (have [\"jsonl\", \"tcp\"])"
            );
            c.serve_mode = v.to_string();
            Ok(())
        },
    },
    KeySpec {
        key: "serve_port",
        doc: "serve: TCP listen port for serve_mode=tcp (0 = OS-assigned, printed on startup)",
        default: || TrainConfig::default().serve_port.to_string(),
        get: |c| c.serve_port.to_string(),
        set: |c, v| {
            c.serve_port = v.parse()?;
            Ok(())
        },
    },
];

fn render_milestones(m: &[u64]) -> String {
    m.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(",")
}

/// Resolve a key through [`KEY_ALIASES`] to its canonical registry name.
pub fn canonical_key(key: &str) -> &str {
    KEY_ALIASES
        .iter()
        .find(|(alias, _)| *alias == key)
        .map(|(_, canon)| *canon)
        .unwrap_or(key)
}

/// Look up a key's [`KeySpec`] (aliases resolved).
pub fn key_spec(key: &str) -> Option<&'static KeySpec> {
    let canon = canonical_key(key);
    CONFIG_KEYS.iter().find(|s| s.key == canon)
}

/// The full valid-key listing (key, default, docstring) — the
/// per-subcommand `--help` table and the tail of every unknown-key error.
pub fn help_table() -> String {
    let mut out = String::from("config keys (--set key=value; [default] shown):\n");
    for s in CONFIG_KEYS {
        out.push_str(&format!("  {:<13} {:<22} {}\n", s.key, format!("[{}]", (s.default)()), s.doc));
    }
    out
}

impl TrainConfig {
    /// Apply one `key=value` override (the CLI `--set` form).
    pub fn set(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value, got {kv:?}"))?;
        self.set_key(k, v)
    }

    /// Apply one override through the key registry. Unknown keys are
    /// rejected with the full valid-key listing.
    pub fn set_key(&mut self, key: &str, value: &str) -> Result<()> {
        let spec = key_spec(key)
            .ok_or_else(|| anyhow!("unknown config key {key:?}\n{}", help_table()))?;
        (spec.set)(self, value).map_err(|e| e.context(format!("config key {}={value:?}", spec.key)))
    }

    /// Render one key's current value (aliases resolved).
    pub fn get_key(&self, key: &str) -> Option<String> {
        key_spec(key).map(|s| (s.get)(self))
    }

    /// The fully-resolved config as a JSON object: every registry key,
    /// rendered by its own `get`. This is what the lab runner writes into
    /// `trial_input.json` and compares for crash-resume validation;
    /// [`Self::from_json`] inverts it exactly (round-trip pinned in the
    /// tests below).
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        for s in CONFIG_KEYS {
            m.insert(s.key.to_string(), Json::Str((s.get)(self)));
        }
        Json::Obj(m)
    }

    /// Build a config from a JSON object of overrides over the defaults.
    /// Values may be JSON strings, numbers or booleans (coerced through
    /// their registry parser); unknown keys are rejected with the full
    /// valid-key listing.
    pub fn from_json(v: &Json) -> Result<TrainConfig> {
        let mut c = TrainConfig::default();
        c.apply_json(v)?;
        Ok(c)
    }

    /// Apply a JSON object of overrides onto `self` (see
    /// [`Self::from_json`]).
    pub fn apply_json(&mut self, v: &Json) -> Result<()> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow!("config overrides must be a JSON object of key: value"))?;
        for (k, val) in obj {
            let s = val.coerce_string().ok_or_else(|| {
                anyhow!("config key {k:?}: value must be a scalar (string/number/bool), got {val:?}")
            })?;
            self.set_key(k, &s)?;
        }
        Ok(())
    }

    /// Parse a config file of key=value lines ('#' comments allowed).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        for (i, line) in std::fs::read_to_string(path)?.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            self.set(line).map_err(|e| anyhow!("{path}:{}: {e}", i + 1))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays() {
        let s = LrSchedule { base: 0.1, milestones: vec![100, 200] };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(99), 0.1);
        assert!((s.at(100) - 0.01).abs() < 1e-9);
        assert!((s.at(200) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn overrides() {
        let mut c = TrainConfig::default();
        c.set("model=cnn_s").unwrap();
        c.set("steps=42").unwrap();
        c.set("milestones=10,20").unwrap();
        c.set("noise=0.7").unwrap();
        assert_eq!(c.model, "cnn_s");
        assert_eq!(c.steps, 42);
        assert_eq!(c.lr.milestones, vec![10, 20]);
        assert!((c.data.noise - 0.7).abs() < 1e-6);
        assert!(c.set("bogus=1").is_err());
        assert!(c.set("nokey").is_err());
        // the cfg_name alias still works and maps onto "cfg"
        c.set("cfg_name=e2m1_gnc_eg8mg1_sr").unwrap();
        assert_eq!(c.cfg_name, "e2m1_gnc_eg8mg1_sr");
        assert_eq!(c.get_key("cfg_name"), Some("e2m1_gnc_eg8mg1_sr".to_string()));
    }

    #[test]
    fn unknown_key_error_lists_every_registry_key() {
        let mut c = TrainConfig::default();
        let msg = format!("{:#}", c.set("bogus=1").unwrap_err());
        assert!(msg.contains("unknown config key \"bogus\""), "{msg}");
        for s in CONFIG_KEYS {
            assert!(msg.contains(s.key), "listing must contain {:?}: {msg}", s.key);
            assert!(msg.contains(s.doc), "listing must contain the doc of {:?}", s.key);
        }
    }

    #[test]
    fn every_registry_key_get_set_round_trips() {
        // self-consistency of the registry: defaults render as the
        // default config's gets, and feeding any get back through set is
        // the identity — the property to_json/from_json relies on
        let c = TrainConfig::default();
        for s in CONFIG_KEYS {
            assert_eq!((s.default)(), (s.get)(&c), "default of {:?}", s.key);
            let mut c2 = c.clone();
            (s.set)(&mut c2, &(s.get)(&c)).unwrap_or_else(|e| panic!("{}: {e:#}", s.key));
            assert_eq!(c2, c, "set(get()) must be the identity for {:?}", s.key);
        }
    }

    #[test]
    fn json_round_trip_is_exact() {
        let mut c = TrainConfig::default();
        c.set("model=resnet_t").unwrap();
        c.set("cfg=e2m1_gnc_eg8mg1_sr").unwrap();
        c.set("steps=77").unwrap();
        c.set("milestones=").unwrap();
        c.set("optimizer=momentum").unwrap();
        c.set("momentum=0.85").unwrap();
        c.set("weight_decay=0.0005").unwrap();
        c.set("noise=1.25").unwrap();
        c.set("out_dir=runs/x").unwrap();
        let j = c.to_json();
        let back = TrainConfig::from_json(&j).unwrap();
        assert_eq!(back, c);
        assert_eq!(back.to_json(), j, "second trip is stable");
        // defaults round-trip too (incl. out_dir = None)
        let d = TrainConfig::default();
        assert_eq!(TrainConfig::from_json(&d.to_json()).unwrap(), d);
    }

    #[test]
    fn apply_json_coerces_scalars_and_rejects_unknown() {
        let v = Json::parse(r#"{"steps": 12, "lr": 0.125, "model": "cnn_t"}"#).unwrap();
        let c = TrainConfig::from_json(&v).unwrap();
        assert_eq!(c.steps, 12);
        assert_eq!(c.model, "cnn_t");
        assert!((c.lr.base - 0.125).abs() < 1e-9);
        let bad = Json::parse(r#"{"stepz": 12}"#).unwrap();
        let msg = format!("{:#}", TrainConfig::from_json(&bad).unwrap_err());
        assert!(msg.contains("stepz") && msg.contains("steps"), "{msg}");
        let nonscalar = Json::parse(r#"{"steps": [1, 2]}"#).unwrap();
        assert!(TrainConfig::from_json(&nonscalar).is_err());
    }

    #[test]
    fn cfg_values_are_validated_at_set_time() {
        let mut c = TrainConfig::default();
        c.set("cfg=fp32").unwrap();
        c.set("cfg=e0m2_gnc_eg8mg1_sr").unwrap();
        let msg = format!("{:#}", c.set("cfg=e2m4_gx_eg8mg1_sr").unwrap_err());
        assert!(msg.contains("gnc"), "token listing expected: {msg}");
        assert_eq!(c.cfg_name, "e0m2_gnc_eg8mg1_sr", "rejected value must not stick");
    }

    #[test]
    fn optimizer_overrides() {
        let mut c = TrainConfig::default();
        assert_eq!(c.optimizer, "sgd", "plain SGD is the default");
        assert_eq!(c.weight_decay, 0.0);
        c.set("optimizer=momentum").unwrap();
        c.set("momentum=0.8").unwrap();
        c.set("weight_decay=0.0005").unwrap();
        assert_eq!(c.optimizer, "momentum");
        assert!((c.momentum - 0.8).abs() < 1e-6);
        assert!((c.weight_decay - 0.0005).abs() < 1e-9);
        let err = c.set("optimizer=adam").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sgd") && msg.contains("momentum"), "{msg}");
        assert_eq!(c.optimizer, "momentum", "a rejected override must not stick");
    }

    #[test]
    fn backend_and_batch_overrides() {
        let mut c = TrainConfig::default();
        assert_eq!(c.backend, Backend::Native, "self-contained native is the default");
        c.set("backend=pjrt").unwrap();
        assert_eq!(c.backend, Backend::Pjrt);
        c.set("backend=native").unwrap();
        assert_eq!(c.backend, Backend::Native);
        assert!(c.set("backend=tpu").is_err());
        c.set("batch=8").unwrap();
        assert_eq!(c.batch, 8);
        assert_eq!(Backend::parse("pjrt").unwrap().name(), "pjrt");
    }

    #[test]
    fn backend_registry_round_trips_and_lists() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        let msg = format!("{:#}", Backend::parse("tpu").unwrap_err());
        for b in Backend::ALL {
            assert!(msg.contains(b.name()), "{msg}");
        }
    }

    #[test]
    fn fault_tolerance_keys_validate_at_set_time() {
        let mut c = TrainConfig::default();
        assert_eq!(c.checkpoint_every, 0, "checkpointing is off by default");
        assert!(c.resume, "resume is a no-op without a checkpoint, so default on");
        assert_eq!(c.on_divergence, "abort", "abort is the pre-PR-8 behavior");
        assert_eq!(c.divergence_window, 0);
        c.set("checkpoint_every=5").unwrap();
        c.set("resume=false").unwrap();
        c.set("on_divergence=halve_lr").unwrap();
        c.set("divergence_window=3").unwrap();
        c.set("divergence_factor=4.5").unwrap();
        assert_eq!(c.checkpoint_every, 5);
        assert!(!c.resume);
        assert_eq!(c.on_divergence, "halve_lr");
        assert_eq!(c.divergence_window, 3);
        assert!((c.divergence_factor - 4.5).abs() < 1e-6);
        let msg = format!("{:#}", c.set("on_divergence=explode").unwrap_err());
        assert!(msg.contains("abort") && msg.contains("rollback") && msg.contains("halve_lr"), "{msg}");
        assert_eq!(c.on_divergence, "halve_lr", "rejected value must not stick");
        assert!(c.set("divergence_factor=1.0").is_err(), "factor must exceed 1");
        assert!(c.set("divergence_factor=inf").is_err());
        assert!(c.set("resume=maybe").is_err());
        // the fault field is NOT a registry key: never rendered, never set
        assert!(c.set("fault=nan_grad@step1").is_err());
        c.fault = Some("nan_grad@step1".to_string());
        assert!(c.to_json().get("fault").is_none(), "fault must not leak into the echo");
    }

    #[test]
    fn serve_keys_validate_at_set_time() {
        let mut c = TrainConfig::default();
        assert_eq!(c.serve_batch_max, 8);
        assert_eq!(c.serve_batch_wait_us, 200);
        assert_eq!(c.serve_mode, "jsonl");
        assert_eq!(c.serve_port, 0);
        c.set("serve_batch_max=32").unwrap();
        c.set("serve_batch_wait_us=500").unwrap();
        c.set("serve_mode=tcp").unwrap();
        c.set("serve_port=7070").unwrap();
        assert_eq!(c.serve_batch_max, 32);
        assert_eq!(c.serve_batch_wait_us, 500);
        assert_eq!(c.serve_mode, "tcp");
        assert_eq!(c.serve_port, 7070);
        assert!(c.set("serve_batch_max=0").is_err(), "batch max must be >= 1");
        let msg = format!("{:#}", c.set("serve_mode=udp").unwrap_err());
        assert!(msg.contains("jsonl") && msg.contains("tcp"), "{msg}");
        assert_eq!(c.serve_mode, "tcp", "rejected value must not stick");
    }

    #[test]
    fn file_parsing() {
        let dir = std::env::temp_dir().join("mls_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.txt");
        std::fs::write(&path, "steps=7 # comment\n\n# full line comment\nlr=0.2\n").unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.steps, 7);
        assert!((c.lr.base - 0.2).abs() < 1e-6);
    }
}
