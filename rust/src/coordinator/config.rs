//! Run configuration: defaults + `key=value` overrides (CLI or file).
//!
//! The format is a flat `key=value` list (one per line in a file, or
//! repeated `--set key=value` on the CLI) — dependency-free and diffable.

use anyhow::{anyhow, Result};

use crate::data::DatasetConfig;

/// Learning-rate schedule: the paper's step decay (x0.1 at milestones).
#[derive(Clone, Debug, PartialEq)]
pub struct LrSchedule {
    pub base: f32,
    /// steps (not epochs — we are step-based) at which lr decays by 10
    pub milestones: Vec<u64>,
}

impl LrSchedule {
    pub fn at(&self, step: u64) -> f32 {
        let decays = self.milestones.iter().filter(|&&m| step >= m).count();
        self.base * 0.1f32.powi(decays as i32)
    }
}

/// Which execution backend runs the train/eval steps.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// The in-crate Alg. 1 trainer ([`crate::nn::train`]): quantized
    /// forward/backward convs on the pass-generic packed-GEMM engine,
    /// zero external dependencies. The default.
    Native,
    /// The PJRT engine over AOT artifacts (needs `make artifacts` and the
    /// `pjrt` cargo feature; the stub errors otherwise).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> Result<Backend> {
        Ok(match s {
            "native" => Backend::Native,
            "pjrt" => Backend::Pjrt,
            _ => anyhow::bail!("unknown backend {s:?} (have \"native\", \"pjrt\")"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// One training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub model: String,
    /// quant config name as in the manifest (e.g. "e2m4_gnc_eg8mg1_sr", "fp32")
    pub cfg_name: String,
    pub backend: Backend,
    pub steps: u64,
    /// batch size of the native backend (the PJRT artifacts bake their
    /// own batch into the manifest)
    pub batch: usize,
    pub eval_every: u64,
    pub eval_batches: u64,
    pub lr: LrSchedule,
    /// native-backend parameter-update rule: "sgd" (plain) or "momentum"
    pub optimizer: String,
    /// momentum coefficient (used when `optimizer=momentum`)
    pub momentum: f32,
    /// L2 weight decay folded into the gradient (0 = off)
    pub weight_decay: f32,
    pub seed: u64,
    pub data: DatasetConfig,
    /// where to write metrics CSV / checkpoints / the per-layer audit
    /// stream (None = no files)
    pub out_dir: Option<String>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "cnn_s".to_string(),
            cfg_name: "e2m4_gnc_eg8mg1_sr".to_string(),
            backend: Backend::Native,
            steps: 300,
            batch: 32,
            eval_every: 50,
            eval_batches: 16,
            lr: LrSchedule { base: 0.05, milestones: vec![150, 250] },
            optimizer: "sgd".to_string(),
            momentum: 0.9,
            weight_decay: 0.0,
            seed: 0,
            data: DatasetConfig::default(),
            out_dir: None,
        }
    }
}

impl TrainConfig {
    /// Apply one `key=value` override.
    pub fn set(&mut self, kv: &str) -> Result<()> {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow!("override must be key=value, got {kv:?}"))?;
        match k {
            "model" => self.model = v.to_string(),
            "cfg" | "cfg_name" => self.cfg_name = v.to_string(),
            "backend" => self.backend = Backend::parse(v)?,
            "batch" => self.batch = v.parse()?,
            "steps" => self.steps = v.parse()?,
            "eval_every" => self.eval_every = v.parse()?,
            "eval_batches" => self.eval_batches = v.parse()?,
            "lr" => self.lr.base = v.parse()?,
            "optimizer" => {
                anyhow::ensure!(
                    crate::nn::optim::OPTIMIZERS.contains(&v),
                    "unknown optimizer {v:?} (have {:?})",
                    crate::nn::optim::OPTIMIZERS
                );
                self.optimizer = v.to_string()
            }
            "momentum" => self.momentum = v.parse()?,
            "weight_decay" => self.weight_decay = v.parse()?,
            "milestones" => {
                self.lr.milestones = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse().map_err(|e| anyhow!("milestone {s:?}: {e}")))
                    .collect::<Result<Vec<u64>>>()?
            }
            "seed" => self.seed = v.parse()?,
            "noise" => self.data.noise = v.parse()?,
            "label_noise" => self.data.label_noise = v.parse()?,
            "data_seed" => self.data.seed = v.parse()?,
            "out_dir" => self.out_dir = Some(v.to_string()),
            _ => anyhow::bail!("unknown config key {k:?}"),
        }
        Ok(())
    }

    /// Parse a config file of key=value lines ('#' comments allowed).
    pub fn load_file(&mut self, path: &str) -> Result<()> {
        for (i, line) in std::fs::read_to_string(path)?.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            self.set(line).map_err(|e| anyhow!("{path}:{}: {e}", i + 1))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_decays() {
        let s = LrSchedule { base: 0.1, milestones: vec![100, 200] };
        assert_eq!(s.at(0), 0.1);
        assert_eq!(s.at(99), 0.1);
        assert!((s.at(100) - 0.01).abs() < 1e-9);
        assert!((s.at(200) - 0.001).abs() < 1e-9);
    }

    #[test]
    fn overrides() {
        let mut c = TrainConfig::default();
        c.set("model=cnn_s").unwrap();
        c.set("steps=42").unwrap();
        c.set("milestones=10,20").unwrap();
        c.set("noise=0.7").unwrap();
        assert_eq!(c.model, "cnn_s");
        assert_eq!(c.steps, 42);
        assert_eq!(c.lr.milestones, vec![10, 20]);
        assert!((c.data.noise - 0.7).abs() < 1e-6);
        assert!(c.set("bogus=1").is_err());
        assert!(c.set("nokey").is_err());
    }

    #[test]
    fn optimizer_overrides() {
        let mut c = TrainConfig::default();
        assert_eq!(c.optimizer, "sgd", "plain SGD is the default");
        assert_eq!(c.weight_decay, 0.0);
        c.set("optimizer=momentum").unwrap();
        c.set("momentum=0.8").unwrap();
        c.set("weight_decay=0.0005").unwrap();
        assert_eq!(c.optimizer, "momentum");
        assert!((c.momentum - 0.8).abs() < 1e-6);
        assert!((c.weight_decay - 0.0005).abs() < 1e-9);
        let err = c.set("optimizer=adam").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sgd") && msg.contains("momentum"), "{msg}");
        assert_eq!(c.optimizer, "momentum", "a rejected override must not stick");
    }

    #[test]
    fn backend_and_batch_overrides() {
        let mut c = TrainConfig::default();
        assert_eq!(c.backend, Backend::Native, "self-contained native is the default");
        c.set("backend=pjrt").unwrap();
        assert_eq!(c.backend, Backend::Pjrt);
        c.set("backend=native").unwrap();
        assert_eq!(c.backend, Backend::Native);
        assert!(c.set("backend=tpu").is_err());
        c.set("batch=8").unwrap();
        assert_eq!(c.batch, 8);
        assert_eq!(Backend::parse("pjrt").unwrap().name(), "pjrt");
    }

    #[test]
    fn file_parsing() {
        let dir = std::env::temp_dir().join("mls_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cfg.txt");
        std::fs::write(&path, "steps=7 # comment\n\n# full line comment\nlr=0.2\n").unwrap();
        let mut c = TrainConfig::default();
        c.load_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.steps, 7);
        assert!((c.lr.base - 0.2).abs() < 1e-6);
    }
}
