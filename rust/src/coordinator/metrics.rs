//! Training metrics: step rows, CSV export, and summaries.

use std::io::Write;
use std::path::Path;

use anyhow::Result;

use crate::util::stats;

#[derive(Clone, Copy, Debug)]
pub struct StepRow {
    pub step: u64,
    pub lr: f32,
    pub loss: f32,
    pub acc: f32,
    pub step_ms: f64,
}

#[derive(Clone, Copy, Debug)]
pub struct EvalRow {
    pub step: u64,
    pub loss: f32,
    pub acc: f32,
}

#[derive(Clone, Debug, Default)]
pub struct MetricsLog {
    pub steps: Vec<StepRow>,
    pub evals: Vec<EvalRow>,
}

impl MetricsLog {
    pub fn record_step(&mut self, row: StepRow) {
        self.steps.push(row);
    }

    pub fn record_eval(&mut self, row: EvalRow) {
        self.evals.push(row);
    }

    /// Mean training loss over the last `n` steps (robust "final loss").
    pub fn final_loss(&self, n: usize) -> f64 {
        let tail: Vec<f64> = self
            .steps
            .iter()
            .rev()
            .take(n)
            .map(|r| r.loss as f64)
            .collect();
        stats::mean(&tail)
    }

    pub fn final_eval_acc(&self) -> Option<f32> {
        self.evals.last().map(|e| e.acc)
    }

    pub fn best_eval_acc(&self) -> Option<f32> {
        self.evals.iter().map(|e| e.acc).fold(None, |m, a| {
            Some(m.map_or(a, |m: f32| m.max(a)))
        })
    }

    pub fn mean_step_ms(&self) -> f64 {
        stats::mean(&self.steps.iter().map(|r| r.step_ms).collect::<Vec<_>>())
    }

    pub fn diverged(&self) -> bool {
        self.steps
            .last()
            .map(|r| !r.loss.is_finite())
            .unwrap_or(false)
    }

    /// Write the loss curve as CSV (step,lr,loss,acc,step_ms).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(&path)?;
        writeln!(f, "step,lr,loss,acc,step_ms")?;
        for r in &self.steps {
            writeln!(f, "{},{},{},{},{:.3}", r.step, r.lr, r.loss, r.acc, r.step_ms)?;
        }
        writeln!(f)?;
        writeln!(f, "eval_step,eval_loss,eval_acc")?;
        for e in &self.evals {
            writeln!(f, "{},{},{}", e.step, e.loss, e.acc)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn log3() -> MetricsLog {
        let mut m = MetricsLog::default();
        for (i, l) in [2.3f32, 1.1, 0.5].iter().enumerate() {
            m.record_step(StepRow { step: i as u64, lr: 0.1, loss: *l, acc: 0.5, step_ms: 10.0 });
        }
        m.record_eval(EvalRow { step: 2, loss: 0.6, acc: 0.8 });
        m
    }

    #[test]
    fn summaries() {
        let m = log3();
        assert!((m.final_loss(2) - 0.8).abs() < 1e-6);
        assert_eq!(m.final_eval_acc(), Some(0.8));
        assert_eq!(m.best_eval_acc(), Some(0.8));
        assert!(!m.diverged());
    }

    #[test]
    fn divergence_detection() {
        let mut m = log3();
        m.record_step(StepRow { step: 3, lr: 0.1, loss: f32::NAN, acc: 0.0, step_ms: 1.0 });
        assert!(m.diverged());
    }

    #[test]
    fn csv_roundtrip() {
        let m = log3();
        let path = std::env::temp_dir().join("mls_metrics_test").join("run.csv");
        m.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("step,lr,loss,acc,step_ms"));
        assert!(text.contains("eval_step"));
        assert_eq!(text.lines().filter(|l| !l.is_empty()).count(), 1 + 3 + 1 + 1);
    }
}
