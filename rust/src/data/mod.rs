//! `synthcifar` — the deterministic synthetic image-classification dataset
//! (DESIGN.md substitution for CIFAR-10/ImageNet).
//!
//! Each class is a fixed random spatial template; a sample is its class
//! template plus Gaussian pixel noise, optionally with label noise. The
//! task is learnable to high accuracy by a small CNN in a few hundred
//! steps, yet sensitive enough to expose the accuracy gaps between numeric
//! formats (the Table II / Table IV orderings). Everything is generated
//! from a PCG32 seed, identically across runs and machines.

use crate::util::rng::Pcg32;

#[derive(Clone, Debug, PartialEq)]
pub struct DatasetConfig {
    pub classes: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    /// template pixel scale
    pub signal: f32,
    /// additive noise sigma (controls task difficulty)
    pub noise: f32,
    /// probability of a wrong label (irreducible error floor)
    pub label_noise: f32,
    pub seed: u64,
}

impl Default for DatasetConfig {
    fn default() -> Self {
        DatasetConfig {
            classes: 10,
            channels: 3,
            height: 16,
            width: 16,
            signal: 1.0,
            // tuned so the Table II / IV orderings separate: fp32 ~0.91
            // test acc, <2,1> within ~1%, ungrouped 1-bit fixed point
            // collapses (see EXPERIMENTS.md)
            noise: 2.0,
            label_noise: 0.1,
            seed: 0,
        }
    }
}

/// The dataset generator: templates fixed by the seed; batches drawn from
/// independent, reproducible streams.
pub struct SynthCifar {
    pub cfg: DatasetConfig,
    templates: Vec<f32>, // [classes, C, H, W]
}

impl SynthCifar {
    pub fn new(cfg: DatasetConfig) -> Self {
        let mut rng = Pcg32::new(cfg.seed, 0x7e3a_717e5);
        let n = cfg.classes * cfg.channels * cfg.height * cfg.width;
        let templates = rng.normal_vec(n, cfg.signal);
        SynthCifar { cfg, templates }
    }

    pub fn sample_elems(&self) -> usize {
        self.cfg.channels * self.cfg.height * self.cfg.width
    }

    /// Generate one batch: returns (images [B, C, H, W] flattened, labels).
    /// `stream` separates train/val/test streams; `index` is the batch id.
    pub fn batch(&self, batch: usize, stream: u64, index: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Pcg32::new(
            self.cfg.seed ^ index.wrapping_mul(0x9E37_79B9_7F4A_7C15),
            stream,
        );
        let k = self.sample_elems();
        let mut images = Vec::with_capacity(batch * k);
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let true_class = rng.below(self.cfg.classes as u32) as usize;
            let label = if rng.uniform() < self.cfg.label_noise {
                rng.below(self.cfg.classes as u32) as i32
            } else {
                true_class as i32
            };
            labels.push(label);
            let t = &self.templates[true_class * k..(true_class + 1) * k];
            for &tv in t {
                images.push(tv + rng.normal() * self.cfg.noise);
            }
        }
        (images, labels)
    }
}

/// Stream ids for the standard splits.
pub mod streams {
    pub const TRAIN: u64 = 1;
    pub const VAL: u64 = 2;
    pub const TEST: u64 = 3;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_batches() {
        let ds = SynthCifar::new(DatasetConfig::default());
        let (x1, y1) = ds.batch(8, streams::TRAIN, 0);
        let (x2, y2) = ds.batch(8, streams::TRAIN, 0);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn batches_differ_by_index_and_stream(){
        let ds = SynthCifar::new(DatasetConfig::default());
        let (x1, _) = ds.batch(8, streams::TRAIN, 0);
        let (x2, _) = ds.batch(8, streams::TRAIN, 1);
        let (x3, _) = ds.batch(8, streams::VAL, 0);
        assert_ne!(x1, x2);
        assert_ne!(x1, x3);
    }

    #[test]
    fn shapes_and_label_range() {
        let cfg = DatasetConfig::default();
        let ds = SynthCifar::new(cfg.clone());
        let (x, y) = ds.batch(16, streams::TEST, 3);
        assert_eq!(x.len(), 16 * 3 * 16 * 16);
        assert_eq!(y.len(), 16);
        assert!(y.iter().all(|&l| (0..10).contains(&l)));
    }

    #[test]
    fn templates_separate_classes() {
        // nearest-template classification on clean-ish data beats chance by far
        let cfg = DatasetConfig { noise: 0.5, label_noise: 0.0, ..Default::default() };
        let ds = SynthCifar::new(cfg);
        let (x, y) = ds.batch(64, streams::TEST, 0);
        let k = ds.sample_elems();
        let mut correct = 0;
        for (i, &label) in y.iter().enumerate() {
            let img = &x[i * k..(i + 1) * k];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..10 {
                let t = &ds.templates[c * k..(c + 1) * k];
                let d: f32 = img.iter().zip(t).map(|(a, b)| (a - b) * (a - b)).sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == label as usize {
                correct += 1;
            }
        }
        assert!(correct >= 58, "nearest-template acc {correct}/64");
    }

    #[test]
    fn label_noise_applied() {
        let cfg = DatasetConfig { label_noise: 1.0, ..Default::default() };
        let ds = SynthCifar::new(cfg);
        let (_, y) = ds.batch(256, streams::TRAIN, 0);
        // with 100% label noise labels are uniform -> many distinct values
        let distinct: std::collections::BTreeSet<i32> = y.into_iter().collect();
        assert!(distinct.len() >= 8);
    }
}
