#!/usr/bin/env python3
"""Validate BENCH_*.json / AUDIT_*.json / lab-runner JSON documents
against their checked-in schemas.

Stdlib-only (CI's build-test job has no pip step), implementing the JSON
Schema subset the bench/audit/lab schemas use: type, const, enum,
required, properties, additionalProperties (as a sub-schema),
minProperties, minimum, maximum, exclusiveMinimum, oneOf (exactly one branch must
match — the audit stream mixes train_step and health records), and for
arrays minItems + items (as a sub-schema applied to every element — the
per-layer audit stream's `layers` array needs it). A malformed report —
missing ratio, empty results block, non-positive throughput, empty audit
stream — fails the build instead of silently shipping in the
bench-trajectory artifact.

Usage: validate_bench.py [--monotonic-steps] <report>... <schema.json>

Every argument but the last is a document to validate against the final
schema argument. A `.jsonl` document is validated line by line (each
non-empty line one instance of the schema — the audit stream and the lab
analysis ranking both use this form); anything else is one JSON document.

With --monotonic-steps, every `.jsonl` document must additionally carry
strictly increasing `step` indices across its train_step records
(records whose "audit" field is "train_step", or that have a "step" but
no "audit" discriminator). Duplicate or backwards steps mean a crashed
run resumed without truncating its audit stream back to the checkpoint
— exactly the bug the fault-tolerance harness exists to catch.
"""
import json
import sys

TYPES = {
    "object": dict,
    "string": str,
    "number": (int, float),
    "boolean": bool,
    "array": list,
}


def check(value, schema, path, errors):
    if "oneOf" in schema:
        branch_errors = []
        for branch in schema["oneOf"]:
            errs = []
            check(value, branch, path, errs)
            branch_errors.append(errs)
        matches = [i for i, errs in enumerate(branch_errors) if not errs]
        if len(matches) != 1:
            if not matches:
                detail = "; ".join(
                    f"branch {i}: {errs[0]}" for i, errs in enumerate(branch_errors)
                )
                errors.append(f"{path}: matches no oneOf branch ({detail})")
            else:
                errors.append(f"{path}: matches oneOf branches {matches}, want exactly 1")
        return
    t = schema.get("type")
    if t is not None:
        py = TYPES[t]
        # bool is an int subclass in Python; keep number strictly numeric
        if isinstance(value, bool) and t != "boolean":
            errors.append(f"{path}: expected {t}, got boolean")
            return
        if not isinstance(value, py):
            errors.append(f"{path}: expected {t}, got {type(value).__name__}")
            return
    if "const" in schema and value != schema["const"]:
        errors.append(f"{path}: expected {schema['const']!r}, got {value!r}")
    if "enum" in schema and value not in schema["enum"]:
        errors.append(f"{path}: {value!r} not one of {schema['enum']}")
    if "minimum" in schema and value < schema["minimum"]:
        errors.append(f"{path}: {value} < minimum {schema['minimum']}")
    if "maximum" in schema and value > schema["maximum"]:
        errors.append(f"{path}: {value} > maximum {schema['maximum']}")
    if "exclusiveMinimum" in schema and value <= schema["exclusiveMinimum"]:
        errors.append(f"{path}: {value} <= exclusiveMinimum {schema['exclusiveMinimum']}")
    if isinstance(value, list):
        if "minItems" in schema and len(value) < schema["minItems"]:
            errors.append(
                f"{path}: has {len(value)} items, needs >= {schema['minItems']}"
            )
        items = schema.get("items")
        if isinstance(items, dict):
            for idx, sub in enumerate(value):
                check(sub, items, f"{path}[{idx}]", errors)
    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                errors.append(f"{path}: missing required key {key!r}")
        if "minProperties" in schema and len(value) < schema["minProperties"]:
            errors.append(
                f"{path}: has {len(value)} properties, needs >= {schema['minProperties']}"
            )
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for key, sub in value.items():
            if key in props:
                check(sub, props[key], f"{path}.{key}", errors)
            elif isinstance(extra, dict):
                check(sub, extra, f"{path}.{key}", errors)


def load_instances(report_path):
    """One (label, parsed-document) pair per schema instance in the file:
    the whole document, or one per non-empty line for `.jsonl`."""
    if report_path.endswith(".jsonl"):
        with open(report_path) as f:
            lines = [(i, ln) for i, ln in enumerate(f, 1) if ln.strip()]
        if not lines:
            raise ValueError("empty jsonl stream")
        return [(f"{report_path}:{i}", json.loads(ln)) for i, ln in lines]
    with open(report_path) as f:
        return [(report_path, json.load(f))]


def check_monotonic_steps(report_path, instances):
    """Strictly increasing `step` over a stream's train_step records —
    duplicates or backwards jumps betray a resume that did not truncate
    the audit stream back to its checkpoint. Returns error strings."""
    errors = []
    last = None  # (step, label)
    for label, rec in instances:
        if not isinstance(rec, dict) or "step" not in rec:
            continue
        if rec.get("audit", "train_step") != "train_step":
            continue  # health / other interleaved records may repeat steps
        step = rec["step"]
        if not isinstance(step, (int, float)) or isinstance(step, bool):
            errors.append(f"{label}: step {step!r} is not a number")
            continue
        if last is not None and step <= last[0]:
            kind = "duplicate" if step == last[0] else "non-monotonic"
            errors.append(
                f"{label}: {kind} step {step} (previous train_step record "
                f"{last[1]} had step {last[0]})"
            )
        last = (step, label)
    return errors


def validate_one(report_path, schema, schema_path, monotonic_steps=False):
    """Validate one file; return True if it passed, printing a verdict."""
    try:
        instances = load_instances(report_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"FAIL {report_path}: unreadable or not JSON: {e}")
        return False
    ok = True
    if monotonic_steps and report_path.endswith(".jsonl"):
        step_errors = check_monotonic_steps(report_path, instances)
        if step_errors:
            print(f"FAIL {report_path}: step indices are not strictly increasing:")
            for e in step_errors:
                print(f"  - {e}")
            ok = False
    for label, report in instances:
        errors = []
        check(report, schema, "$", errors)
        if not errors:
            continue
        if isinstance(report, dict) and "awaiting first measured run" in str(
            report.get("status", "")
        ) and not report.get("results"):
            # the committed tree ships an explicitly-labeled placeholder
            # (no toolchain in the authoring container); it is still a
            # failure — only a measured report may pass the gate
            print(
                f"FAIL {label}: committed placeholder, not a measured report — "
                f"run `cargo bench` to produce one (status: {report['status'][:80]}...)"
            )
            ok = False
            continue
        print(f"FAIL {label} does not match {schema_path}:")
        for e in errors:
            print(f"  - {e}")
        ok = False
    if ok:
        n = len(instances)
        suffix = f" ({n} records)" if n > 1 or report_path.endswith(".jsonl") else ""
        print(f"OK {report_path} matches {schema_path}{suffix}")
    return ok


def main():
    argv = sys.argv[1:]
    monotonic_steps = "--monotonic-steps" in argv
    argv = [a for a in argv if a != "--monotonic-steps"]
    if len(argv) < 2:
        sys.exit(__doc__)
    report_paths, schema_path = argv[:-1], argv[-1]
    with open(schema_path) as f:
        schema = json.load(f)
    results = [
        validate_one(p, schema, schema_path, monotonic_steps) for p in report_paths
    ]
    if not all(results):
        sys.exit(1)


if __name__ == "__main__":
    main()
