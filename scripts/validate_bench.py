#!/usr/bin/env python3
"""Validate a BENCH_*.json / AUDIT_*.json report against its checked-in
schema.

Stdlib-only (CI's build-test job has no pip step), implementing the JSON
Schema subset the bench/audit schemas use: type, const, required,
properties, additionalProperties (as a sub-schema), minProperties,
minimum, exclusiveMinimum, and for arrays minItems + items (as a
sub-schema applied to every element — the per-layer audit stream's
`layers` array needs it). A malformed report — missing ratio, empty
results block, non-positive throughput, empty audit stream — fails the
build instead of silently shipping in the bench-trajectory artifact.

Usage: validate_bench.py <report.json> <schema.json>
"""
import json
import sys

TYPES = {
    "object": dict,
    "string": str,
    "number": (int, float),
    "boolean": bool,
    "array": list,
}


def check(value, schema, path, errors):
    t = schema.get("type")
    if t is not None:
        py = TYPES[t]
        # bool is an int subclass in Python; keep number strictly numeric
        if isinstance(value, bool) and t != "boolean":
            errors.append(f"{path}: expected {t}, got boolean")
            return
        if not isinstance(value, py):
            errors.append(f"{path}: expected {t}, got {type(value).__name__}")
            return
    if "const" in schema and value != schema["const"]:
        errors.append(f"{path}: expected {schema['const']!r}, got {value!r}")
    if "minimum" in schema and value < schema["minimum"]:
        errors.append(f"{path}: {value} < minimum {schema['minimum']}")
    if "exclusiveMinimum" in schema and value <= schema["exclusiveMinimum"]:
        errors.append(f"{path}: {value} <= exclusiveMinimum {schema['exclusiveMinimum']}")
    if isinstance(value, list):
        if "minItems" in schema and len(value) < schema["minItems"]:
            errors.append(
                f"{path}: has {len(value)} items, needs >= {schema['minItems']}"
            )
        items = schema.get("items")
        if isinstance(items, dict):
            for idx, sub in enumerate(value):
                check(sub, items, f"{path}[{idx}]", errors)
    if isinstance(value, dict):
        for key in schema.get("required", []):
            if key not in value:
                errors.append(f"{path}: missing required key {key!r}")
        if "minProperties" in schema and len(value) < schema["minProperties"]:
            errors.append(
                f"{path}: has {len(value)} properties, needs >= {schema['minProperties']}"
            )
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for key, sub in value.items():
            if key in props:
                check(sub, props[key], f"{path}.{key}", errors)
            elif isinstance(extra, dict):
                check(sub, extra, f"{path}.{key}", errors)


def main():
    if len(sys.argv) != 3:
        sys.exit(__doc__)
    report_path, schema_path = sys.argv[1], sys.argv[2]
    try:
        with open(report_path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        sys.exit(f"FAIL {report_path}: unreadable or not JSON: {e}")
    with open(schema_path) as f:
        schema = json.load(f)
    errors = []
    check(report, schema, "$", errors)
    if errors:
        if "awaiting first measured run" in str(report.get("status", "")) and not report.get(
            "results"
        ):
            # the committed tree ships an explicitly-labeled placeholder
            # (no toolchain in the authoring container); it is still a
            # failure — only a measured report may pass the gate
            print(
                f"FAIL {report_path}: committed placeholder, not a measured report — "
                f"run `cargo bench` to produce one (status: {report['status'][:80]}...)"
            )
            sys.exit(1)
        print(f"FAIL {report_path} does not match {schema_path}:")
        for e in errors:
            print(f"  - {e}")
        sys.exit(1)
    print(f"OK {report_path} matches {schema_path}")


if __name__ == "__main__":
    main()
