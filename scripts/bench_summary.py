#!/usr/bin/env python3
"""Render the measured BENCH_*.json perf trajectory as GitHub-flavored
markdown for the CI job summary.

Stdlib-only (CI's build-test job has no pip step). For each report this
prints a section with the SIMD dispatch path the run used (when the
report carries one) and a table of every speedup ratio — the numbers
ROADMAP's perf-trajectory item tracks (packed_vs_planar_serial,
simd_vs_scalar_serial, quantize_simd_vs_scalar, step_vs_sum_of_parts,
...). CI appends the output to $GITHUB_STEP_SUMMARY after the bench
smoke, so every push publishes its measured ratios on the job page even
though the committed JSONs stay null placeholders (the authoring
container has no Rust toolchain).

Usage: bench_summary.py <BENCH_report.json>...

A missing or unreadable report renders as a note instead of failing:
the summary step must never mask the real bench/validate verdicts.
"""
import json
import sys


def render(path):
    try:
        with open(path) as f:
            report = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"### `{path}`", "", f"_not available: {e}_", ""]
    lines = [f"### `{path}` — {report.get('bench', '?')}", ""]
    mode = "smoke" if report.get("smoke") else "full"
    simd = report.get("simd")
    context = [f"{mode} run"]
    if simd is not None:
        context.append(f"simd dispatch: `{simd}`")
    if report.get("threads") is not None:
        context.append(f"{report['threads']:g} threads")
    bytes_per_step = report.get("bytes_allocated_per_step")
    if bytes_per_step is not None:
        # the step-arena contract: a warm training step allocates exactly
        # 0 bytes — any other number is a regression worth seeing here
        verdict = "zero-alloc" if bytes_per_step == 0 else "REGRESSION"
        context.append(
            f"warm arena step: {bytes_per_step:g} heap bytes ({verdict})"
        )
    if report.get("p50_us") is not None:
        # bench_serve: the served-request latency floor on the
        # quantize-once cache (enqueue-free, warm batch-1 forwards)
        context.append(
            f"served latency p50 {report['p50_us']:.1f}us"
            f" / p99 {report.get('p99_us', 0):.1f}us"
        )
    bytes_per_request = report.get("bytes_allocated_per_request")
    if bytes_per_request is not None:
        context.append(
            f"warm served request: {bytes_per_request:g} heap bytes"
        )
    lines.append(", ".join(context))
    lines.append("")
    ratios = report.get("ratios") or {}
    measured = {k: v for k, v in ratios.items() if isinstance(v, (int, float))}
    if measured:
        lines += ["| ratio | value |", "|---|---|"]
        lines += [f"| `{k}` | {v:.3f}x |" for k, v in measured.items()]
    else:
        lines.append("_no measured ratios (placeholder report)_")
    lines.append("")
    return lines


def main():
    if len(sys.argv) < 2:
        sys.exit(__doc__)
    out = ["## Bench trajectory", ""]
    for path in sys.argv[1:]:
        out += render(path)
    print("\n".join(out))


if __name__ == "__main__":
    main()
