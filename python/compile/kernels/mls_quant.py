"""Pallas kernel: MLS dynamic quantization (Alg. 2) -- the L1 hot-spot.

The kernel fake-quantizes one 2-D view ``(groups, elements-per-group)`` of a
tensor. The grid iterates over group blocks; each program:

  1. loads a ``(G_b, L)`` block of the tensor plus the matching rounding
     offsets into VMEM,
  2. reduces the per-group maxima ``S_r`` (row max),
  3. derives the hardware group scale ``S_g`` in <E_g, M_g> (ceil-rounded
     fraction, carry into the clipped exponent -- Alg. 2 lines 4-8),
  4. quantizes every element to <E_x, M_x> with stochastic rounding and
     IEEE-754 gradual underflow (lines 9-16),
  5. writes the dequantized block and the per-group scales.

The tensor-wise scale ``S_t`` (a single fp32 max, Alg. 2 line 3) is computed
outside the kernel -- it is a whole-tensor reduction that XLA fuses into the
producer; its cost is part of the DQ overhead row of Table VI either way.

TPU mapping (DESIGN.md "Hardware adaptation"): one group block = one VMEM
tile (the adder-tree unit's local buffer analog); the row-max + quantize is
VPU element work; BlockSpec expresses the HBM->VMEM schedule the paper's
accelerator realises with its local accumulators. ``interpret=True``
everywhere: the CPU PJRT plugin cannot run Mosaic custom-calls, and all
correctness claims are made on the interpret path.

VMEM budget (<= 4 MiB per block, documented per DESIGN.md "Perf"): with the
default block of 8 groups x L <= 16384 elements x 3 resident f32 planes
(x, r, q) the footprint is 8*16384*4*3 = 1.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from compile.qconfig import QuantConfig
    from compile.kernels import ref
except ImportError:  # script-style import
    from qconfig import QuantConfig  # type: ignore
    import ref  # type: ignore

# Upper bound on groups handled by one program (tuned in the perf pass; see
# EXPERIMENTS.md section Perf for the block-shape iteration log). On the CPU
# interpret path a single whole-tensor block both avoids the per-grid-step
# while-loop (5x faster XLA compile of the artifact) and runs fastest; the
# largest tensor in the shipped models is 512 groups x 256 elements = 512 KiB
# per resident f32 plane, comfortably within the 4 MiB VMEM budget the
# DESIGN.md TPU mapping assumes.
MAX_GROUP_BLOCK = 4096


def _quant_block_kernel(x_ref, r_ref, st_ref, q_ref, sg_ref, *, cfg: QuantConfig):
    """One grid step: fake-quantize a (G_b, L) block of grouped values."""
    x = x_ref[...]
    r = r_ref[...]
    s_t = st_ref[0, 0]
    s_t_safe = jnp.where(s_t > 0, s_t, jnp.float32(1.0))

    sign = jnp.sign(x)
    s_r = jnp.max(jnp.abs(x), axis=1, keepdims=True)          # (G_b, 1)
    sgf = s_r / s_t_safe
    s_g = ref.quantize_group_scale(sgf, cfg.e_g, cfg.m_g)      # (G_b, 1)
    xf = jnp.abs(x) / (s_g * s_t_safe)
    xbar = ref.quantize_element(xf, cfg.e_x, cfg.m_x, r)
    q = sign * s_t_safe * s_g * xbar
    q = jnp.where(s_t > 0, q, jnp.zeros_like(q))

    q_ref[...] = q.astype(jnp.float32)
    sg_ref[...] = s_g.astype(jnp.float32)


def _pick_group_block(n_groups: int) -> int:
    """Largest divisor of n_groups that is <= MAX_GROUP_BLOCK."""
    for gb in range(min(MAX_GROUP_BLOCK, n_groups), 0, -1):
        if n_groups % gb == 0:
            return gb
    return 1


@functools.partial(jax.jit, static_argnames=("cfg",))
def mls_fake_quant_2d(x2d, r2d, cfg: QuantConfig):
    """Pallas fake-quant over a pre-grouped 2-D view (groups, group_len).

    Returns (q2d, s_g) where s_g has shape (groups, 1).
    """
    n_groups, group_len = x2d.shape
    gb = _pick_group_block(n_groups)
    s_t = jnp.max(jnp.abs(x2d)).reshape(1, 1)

    kernel = functools.partial(_quant_block_kernel, cfg=cfg)
    q2d, sg = pl.pallas_call(
        kernel,
        grid=(n_groups // gb,),
        in_specs=[
            pl.BlockSpec((gb, group_len), lambda i: (i, 0)),
            pl.BlockSpec((gb, group_len), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((gb, group_len), lambda i: (i, 0)),
            pl.BlockSpec((gb, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_groups, group_len), jnp.float32),
            jax.ShapeDtypeStruct((n_groups, 1), jnp.float32),
        ],
        interpret=True,
    )(x2d.astype(jnp.float32), r2d.astype(jnp.float32), s_t)
    return q2d, sg


def _to_grouped_2d(x, grouping: str):
    """Reshape/transpose an N-D tensor to (groups, group_len) plus the
    callable that undoes it. Grouping follows ref.group_axes semantics."""
    shape = x.shape
    if grouping == "none":
        flat = x.reshape(1, -1)
        return flat, lambda q: q.reshape(shape)
    if grouping == "first":
        flat = x.reshape(shape[0], -1)
        return flat, lambda q: q.reshape(shape)
    if grouping == "second":
        perm = (1, 0) + tuple(range(2, x.ndim))
        xt = jnp.transpose(x, perm)
        tshape = xt.shape
        flat = xt.reshape(shape[1], -1)
        return flat, lambda q: jnp.transpose(q.reshape(tshape), perm)
    if grouping == "both":
        flat = x.reshape(shape[0] * shape[1], -1)
        return flat, lambda q: q.reshape(shape)
    raise ValueError(f"unknown grouping {grouping!r}")


def mls_fake_quant(x, cfg: QuantConfig, r=None):
    """N-D fake-quant through the Pallas kernel; drop-in replacement for
    ref.mls_fake_quant (bit-exact on identical inputs)."""
    if not cfg.enabled:
        return jnp.asarray(x, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    if r is None or cfg.rounding == "nearest":
        r = jnp.zeros_like(x)
    x2d, undo = _to_grouped_2d(x, cfg.grouping)
    r2d, _ = _to_grouped_2d(jnp.asarray(r, jnp.float32), cfg.grouping)
    q2d, _sg = mls_fake_quant_2d(x2d, r2d, cfg)
    return undo(q2d)
