"""Pure-jnp reference (oracle) for the MLS tensor format.

This file is the CANONICAL numerics spec of the repo. Three implementations
must agree with it bit-exactly on the same inputs:

  1. the Pallas kernel (kernels/mls_quant.py), checked by pytest,
  2. the Rust bit-accurate quantizer (rust/src/mls/), checked against
     golden vectors emitted by python/tests/test_golden.py,
  3. the integer-path convolution arithmetic (kernels/lowbit_conv.py and
     rust/src/arith/), checked against the float fake-quant path.

Format definition (paper Sec. IV + V-C, Alg. 2) — <E, M> with no sign bit:

  exponent code c in [0, 2^E - 1]
    c >= 1  (normal):     value = (1 + man / 2^M) * 2^(-c)
    c == 0  (subnormal):  value = (     man / 2^M) * 2^(emin)
  where emin = 1 - 2^E is the minimum normal exponent. This yields
  2^E - 1 normal levels (exponents -1 .. 1-2^E) plus a gradual-underflow
  level, exactly the "minimum value of exponent represents underflow"
  convention of Sec. V-C. Mantissa rounding saturates within its exponent
  level (Alg. 2 line 13: Clip(SRound(.), 0, 2^M - 1)) -- no carry, mirroring
  the paper's float simulation and the hardware's truncate-clip datapath.

  NearestRound(x) is floor(x + 0.5) (round-half-up) so that the stochastic
  rounding SRound(x, r) = NearestRound(x + r), r ~ U[-1/2, 1/2), is a pure
  add-then-floor -- identical in jnp, Pallas and Rust.

Exponent/fraction extraction uses the IEEE-754 bit pattern directly
(the paper: "in the hardware design, the exponent and mantissa are obtained
directly"), which is exact, and which both jnp and Rust reproduce verbatim.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

try:  # package-style import (pytest from python/)
    from compile.qconfig import QuantConfig
except ImportError:  # script-style import
    from qconfig import QuantConfig  # type: ignore


# --------------------------------------------------------------------------
# IEEE-754 f32 field extraction (exact, branch-free, jnp + pallas friendly)
# --------------------------------------------------------------------------

def f32_exponent(x):
    """Unbiased exponent e of |x| = f * 2^e with f in [1, 2).

    f32 denormals and zero map to e = -127 which is always below any MLS
    emin, i.e. they take the gradual-underflow path.
    """
    bits = jnp.asarray(x, jnp.float32).view(jnp.uint32)
    return (jnp.right_shift(bits, jnp.uint32(23)) & jnp.uint32(0xFF)).astype(jnp.int32) - 127


def f32_fraction(x):
    """Fraction f in [1, 2) of |x| (garbage for zero/denormal inputs; callers
    must select the underflow branch for those)."""
    bits = jnp.asarray(x, jnp.float32).view(jnp.uint32)
    frac_bits = (bits & jnp.uint32(0x007FFFFF)) | jnp.uint32(0x3F800000)
    return frac_bits.view(jnp.float32)



def exp2i(k):
    """EXACT 2^k for integer k (vectorized), built from the IEEE-754 bit
    pattern. XLA lowers exp2 to a polynomial approximation on CPU that can
    be off by several ulp even for integer arguments (e.g. 2^-15), which
    would break bit-exactness against the Rust mirror (format::exp2i).
    Handles the normal range via the exponent field and [-149, -127] via
    subnormal bits; inputs are clipped to [-149, 127] (all call sites stay
    within that range by construction)."""
    k = jnp.asarray(k, jnp.int32)
    kn = jnp.clip(k, -126, 127)
    normal = jnp.left_shift((kn + 127).astype(jnp.uint32), jnp.uint32(23)).view(jnp.float32)
    sub_shift = jnp.clip(k + 149, 0, 22).astype(jnp.uint32)
    sub = jnp.left_shift(jnp.uint32(1), sub_shift).view(jnp.float32)
    return jnp.where(k >= -126, normal, jnp.where(k >= -149, sub, jnp.float32(0.0)))


# --------------------------------------------------------------------------
# Element quantization  (Alg. 2 lines 9-16)
# --------------------------------------------------------------------------

def quantize_element(xf, e_x: int, m_x: int, r):
    """Quantize xf (>= 0, already divided by S_t * S_g, so xf <= 1) to the
    <E_x, M_x> element format. ``r`` is the rounding offset tensor:
    zeros for nearest rounding, U[-1/2, 1/2) for stochastic rounding.

    Returns the dequantized float value (the paper's float simulation).
    """
    xf = jnp.asarray(xf, jnp.float32)
    emin = 1 - 2 ** e_x          # minimum normal exponent
    two_m = np.float32(2.0 ** m_x)

    exp = f32_exponent(xf)

    # Normal path: clip exponent to [emin, -1] (Alg. 2 line 15), recompute
    # the fraction against the clipped exponent so that overflow (xf == 1.0,
    # exponent 0) saturates via the mantissa clip below.
    exp_cl = jnp.clip(exp, emin, -1)
    y = xf * exp2i(-exp_cl)       # xf / 2^exp_cl
    man_n = jnp.floor((y - 1.0) * two_m + r + 0.5)
    man_n = jnp.clip(man_n, 0.0, two_m - 1.0)
    q_n = (1.0 + man_n / two_m) * exp2i(exp_cl)

    # Gradual-underflow path (Alg. 2 lines 11-14): xf < 2^emin is encoded
    # with an implicit leading 0 at level emin.
    man_s = jnp.floor(xf * np.float32(2.0 ** (m_x - emin)) + r + 0.5)
    man_s = jnp.clip(man_s, 0.0, two_m - 1.0)
    q_s = man_s * np.float32(2.0 ** (emin - m_x))

    # E == 0 has no normal levels (2^E - 1 == 0): everything is fixed point
    # (the paper's "single number" rows). Otherwise IEEE-style underflow.
    if e_x == 0:
        return q_s.astype(jnp.float32)
    underflow = xf < np.float32(2.0 ** emin)
    return jnp.where(underflow, q_s, q_n).astype(jnp.float32)


def element_codes(xf, e_x: int, m_x: int, r):
    """Same as quantize_element but returns the stored integer fields
    (exponent code c in [0, 2^E - 1], mantissa in [0, 2^M - 1]) used by the
    integer-path arithmetic and the golden cross-layer tests."""
    xf = jnp.asarray(xf, jnp.float32)
    emin = 1 - 2 ** e_x
    two_m = np.float32(2.0 ** m_x)

    exp = f32_exponent(xf)
    exp_cl = jnp.clip(exp, emin, -1)
    y = xf * exp2i(-exp_cl)
    man_n = jnp.clip(jnp.floor((y - 1.0) * two_m + r + 0.5), 0.0, two_m - 1.0)
    man_s = jnp.clip(
        jnp.floor(xf * np.float32(2.0 ** (m_x - emin)) + r + 0.5), 0.0, two_m - 1.0
    )

    if e_x == 0:  # fixed point: all codes 0 (see quantize_element)
        return jnp.zeros_like(exp_cl), man_s.astype(jnp.int32)
    underflow = xf < np.float32(2.0 ** emin)
    code = jnp.where(underflow, 0, -exp_cl).astype(jnp.int32)  # c = -exp (normal), 0 (sub)
    man = jnp.where(underflow, man_s, man_n).astype(jnp.int32)
    return code, man


def decode_element(code, man, e_x: int, m_x: int):
    """Inverse of element_codes: stored fields -> float value."""
    emin = 1 - 2 ** e_x
    two_m = np.float32(2.0 ** m_x)
    code = jnp.asarray(code, jnp.int32)
    man_f = jnp.asarray(man, jnp.float32)
    normal = code >= 1
    q_n = (1.0 + man_f / two_m) * exp2i(-code)
    q_s = man_f * np.float32(2.0 ** (emin - m_x))
    return jnp.where(normal, q_n, q_s).astype(jnp.float32)


# --------------------------------------------------------------------------
# Group-scale quantization  (Alg. 2 lines 4-8)
# --------------------------------------------------------------------------

def quantize_group_scale(sgf, e_g: int, m_g: int):
    """Quantize sgf = S_r / S_t in [0, 1] to the <E_g, M_g> group format.

    Ceil-rounds the fraction (Alg. 2 line 7) with carry into the exponent so
    that S_g >= sgf always holds (dominance: elements never exceed 1 after
    group scaling). Exponent range is [1 - 2^E_g, 0] (Alg. 2 line 6; 0 is
    reachable because the max group has sgf == 1). All-zero groups get the
    smallest scale so the element divide stays finite.
    """
    sgf = jnp.asarray(sgf, jnp.float32)
    egmin = 1 - 2 ** e_g
    two_mg = np.float32(2.0 ** m_g)

    exp = f32_exponent(sgf)
    exp_cl = jnp.clip(exp, egmin, 0)
    y = sgf * exp2i(-exp_cl)
    man = jnp.ceil((y - 1.0) * two_mg)
    # Carry: man == 2^M_g means the fraction hit 2.0 -> bump the exponent.
    carry = man >= two_mg
    man = jnp.where(carry, 0.0, jnp.clip(man, 0.0, two_mg - 1.0))
    exp_cl = jnp.clip(exp_cl + carry.astype(jnp.int32), egmin, 0)
    sg = (1.0 + man / two_mg) * exp2i(exp_cl)
    # Zero / below-minimum groups: pin to the smallest representable scale.
    # The pin is clamped to a normal f32 (2^-126) so the float simulation
    # never divides by a flushed-to-zero 2^egmin (egmin is -255 for E_g=8);
    # such groups hold only zeros/denormals, which quantize to 0 anyway.
    egpin = max(egmin, -126)
    tiny = sgf <= np.float32(2.0 ** egpin)
    sg = jnp.where(tiny, np.float32(2.0 ** egpin), sg)
    return sg.astype(jnp.float32)


def group_scale_codes(sgf, e_g: int, m_g: int):
    """Stored fields (exponent code in [0, 2^E_g - 1] meaning 2^-c, mantissa)
    of the group scale; used by the shift-add unit (Eq. 8) and goldens."""
    sgf = jnp.asarray(sgf, jnp.float32)
    egmin = 1 - 2 ** e_g
    two_mg = np.float32(2.0 ** m_g)
    exp = f32_exponent(sgf)
    exp_cl = jnp.clip(exp, egmin, 0)
    y = sgf * exp2i(-exp_cl)
    man = jnp.ceil((y - 1.0) * two_mg)
    carry = man >= two_mg
    man = jnp.where(carry, 0.0, jnp.clip(man, 0.0, two_mg - 1.0))
    exp_cl = jnp.clip(exp_cl + carry.astype(jnp.int32), egmin, 0)
    egpin = max(egmin, -126)
    tiny = sgf <= np.float32(2.0 ** egpin)
    exp_cl = jnp.where(tiny, egpin, exp_cl)
    man = jnp.where(tiny, jnp.zeros_like(man), man)
    return (-exp_cl).astype(jnp.int32), man.astype(jnp.int32)


# --------------------------------------------------------------------------
# Grouping helpers
# --------------------------------------------------------------------------

def group_axes(grouping: str, ndim: int):
    """Axes reduced when computing the group max of an ndim tensor."""
    if grouping == "none":
        return tuple(range(ndim))
    if grouping == "first":
        return tuple(range(1, ndim))
    if grouping == "second":
        return (0,) + tuple(range(2, ndim))
    if grouping == "both":
        return tuple(range(2, ndim))
    raise ValueError(f"unknown grouping {grouping!r}")


def group_max(x, grouping: str):
    """Per-group maximum of |x| with keepdims (broadcastable over x)."""
    axes = group_axes(grouping, x.ndim)
    return jnp.max(jnp.abs(x), axis=axes, keepdims=True)


# --------------------------------------------------------------------------
# Full dynamic quantization (Alg. 2) -- fake-quant (dequantized) output
# --------------------------------------------------------------------------

def mls_fake_quant(x, cfg: QuantConfig, r=None):
    """DynamicQuantization + dequantize: the float-simulation the paper runs
    on GPU. Returns a tensor of the same shape as x.

    r: rounding-offset tensor with the same shape as x (U[-1/2, 1/2) for
    stochastic rounding). None = nearest rounding (zeros).
    """
    if not cfg.enabled:
        return jnp.asarray(x, jnp.float32)
    x = jnp.asarray(x, jnp.float32)
    if r is None or cfg.rounding == "nearest":
        r = jnp.zeros_like(x)

    sign = jnp.sign(x)
    s_r = group_max(x, cfg.grouping)                 # per-group max, keepdims
    s_t = jnp.max(s_r)                               # tensor scale (fp32)
    s_t_safe = jnp.where(s_t > 0, s_t, jnp.float32(1.0))
    sgf = s_r / s_t_safe
    s_g = quantize_group_scale(sgf, cfg.e_g, cfg.m_g)
    xf = jnp.abs(x) / (s_g * s_t_safe)
    xbar = quantize_element(xf, cfg.e_x, cfg.m_x, r)
    q = sign * s_t_safe * s_g * xbar
    return jnp.where(s_t > 0, q, jnp.zeros_like(q)).astype(jnp.float32)


def mls_quantize_fields(x, cfg: QuantConfig, r=None):
    """Full decomposition into stored fields, for goldens / integer path.

    Returns dict with: sign (in {-1,0,1}), s_t (scalar f32), s_g (group f32),
    sg_exp_code / sg_man (group-shaped int32), x_exp_code / x_man
    (element-shaped int32), and q (dequantized f32, == mls_fake_quant).
    """
    x = jnp.asarray(x, jnp.float32)
    if r is None or cfg.rounding == "nearest":
        r = jnp.zeros_like(x)
    sign = jnp.sign(x).astype(jnp.int32)
    s_r = group_max(x, cfg.grouping)
    s_t = jnp.max(s_r)
    s_t_safe = jnp.where(s_t > 0, s_t, jnp.float32(1.0))
    sgf = s_r / s_t_safe
    sg_exp, sg_man = group_scale_codes(sgf, cfg.e_g, cfg.m_g)
    s_g = quantize_group_scale(sgf, cfg.e_g, cfg.m_g)
    xf = jnp.abs(x) / (s_g * s_t_safe)
    x_exp, x_man = element_codes(xf, cfg.e_x, cfg.m_x, r)
    xbar = decode_element(x_exp, x_man, cfg.e_x, cfg.m_x)
    q = sign.astype(jnp.float32) * s_t_safe * s_g * xbar
    q = jnp.where(s_t > 0, q, jnp.zeros_like(q))
    return {
        "sign": sign,
        "s_t": jnp.where(s_t > 0, s_t, jnp.float32(0.0)),
        "s_g": s_g,
        "sg_exp_code": sg_exp,
        "sg_man": sg_man,
        "x_exp_code": x_exp,
        "x_man": x_man,
        "q": q.astype(jnp.float32),
    }


# --------------------------------------------------------------------------
# Quantization-error metric (Fig. 7)
# --------------------------------------------------------------------------

def average_relative_error(x, cfg: QuantConfig):
    """ARE = mean|q(x) - x| / mean|x| (nearest rounding), the per-layer
    quantization-error statistic plotted in Fig. 7."""
    import dataclasses as _dc

    x = jnp.asarray(x, jnp.float32)
    q = mls_fake_quant(x, _dc.replace(cfg, rounding="nearest"))
    denom = jnp.mean(jnp.abs(x))
    denom = jnp.where(denom > 0, denom, jnp.float32(1.0))
    return jnp.mean(jnp.abs(q - x)) / denom


# --------------------------------------------------------------------------
# Reference integer-path arithmetic (Eq. 7) on grouped blocks
# --------------------------------------------------------------------------

def intra_group_mac_ref(w_fields, a_fields, e_x: int, m_x: int):
    """Integer intra-group MAC (Eq. 7) over the last axis.

    w_fields / a_fields: dicts with sign (+-1/0), x_exp_code, x_man arrays
    of shape (..., L); the group axis is everything but the last. Returns
    the integer partial sums P (int32 -- jax runs without x64 here; the
    Rust simulator re-runs the same MAC in i64 to verify headroom) and the
    fixed-point position: P_real = P * 2^(scale_log2).

    Caller must ensure product_bits + ceil(log2(L)) + 1 <= 31 (true for all
    paper configs: <2,4> -> 14 bits + K*K sums).
    """
    emin = 1 - 2 ** e_x
    two_m = 2 ** m_x

    def frac_int(f):
        # (M+1)-bit integer fraction: man + 2^M implicit bit when normal.
        return jnp.where(f["x_exp_code"] >= 1, f["x_man"] + two_m, f["x_man"]).astype(jnp.int32)

    def exp_val(f):
        # actual exponent: -code (normal), emin (subnormal)
        return jnp.where(f["x_exp_code"] >= 1, -f["x_exp_code"], emin).astype(jnp.int32)

    fw, fa = frac_int(w_fields), frac_int(a_fields)
    ew, ea = exp_val(w_fields), exp_val(a_fields)
    sw = w_fields["sign"].astype(jnp.int32)
    sa = a_fields["sign"].astype(jnp.int32)
    shift = (ew - emin) + (ea - emin)          # in [0, 2*(2^E - 2)]
    prod = sw * sa * fw * fa * jnp.left_shift(jnp.int32(1), shift)
    p = jnp.sum(prod, axis=-1)
    scale_log2 = 2 * emin - 2 * m_x
    return p, scale_log2
