"""Pallas kernel: low-bit tensor convolution arithmetic (Eq. 6-8).

Demonstrates, in-kernel, the paper's hardware datapath on the *stored
integer fields* of two MLS tensors:

  intra-group (Eq. 7):  (M+1)-bit integer fraction products, aligned by a
      <= 2*(2^E - 2)-bit shift, accumulated in an INTEGER register whose
      width is the Sec. V-C analysis (2M + 2^{E+1} - 2 product bits plus
      log2(L) accumulation headroom);
  group scale (Eq. 8):  S_p = S_g^w * S_g^a is a <E, 2> value whose fraction
      is one of {1, 1.5, 2.25} = {4, 6, 9} / 4 -- applied as exact
      shift-adds (integer multiply by 4/6/9, then a power-of-two exponent);
  inter-group:          floating-point adder tree (the only FloatAdd the
      datapath keeps -- Table VI row "Conv / FloatAdd").

The kernel computes dot products between a weight block and a batch of
activation patches laid out im2col-style:

  weights:    fields of shape (G, L)      -- G groups (ci), L = K*K taps
  activation: fields of shape (X, G, L)   -- X output positions
  output:     z of shape (X,)             -- one output channel's pixels

and is validated against the float fake-quant path in pytest. The training
graph itself uses fake-quant + XLA conv (exactly the paper's GPU
simulation); this kernel plus rust/src/arith/ carry the hardware-exactness
claims.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from compile.qconfig import QuantConfig
except ImportError:  # script-style import
    from qconfig import QuantConfig  # type: ignore


def _lowbit_dot_kernel(
    wf_ref, we_ref, ws_ref, wg_ref,
    af_ref, ae_ref, as_ref, ag_ref,
    z_ref, *, cfg: QuantConfig,
):
    """One grid step: X_b output positions against the full (G, L) weights.

    Field refs: f = integer fraction (man, plus implicit bit info in e),
    e = exponent code, s = sign, g = packed group-scale codes (exp_code*4 +
    man combined at trace time -- see pack_group_codes).
    """
    emin = 1 - 2 ** cfg.e_x
    two_m = 2 ** cfg.m_x

    w_man, w_code, w_sign = wf_ref[...], we_ref[...], ws_ref[...]        # (G, L)
    a_man, a_code, a_sign = af_ref[...], ae_ref[...], as_ref[...]        # (X_b, G, L)

    def frac_int(man, code):
        return jnp.where(code >= 1, man + two_m, man)

    def exp_val(code):
        return jnp.where(code >= 1, -code, emin)

    fw = frac_int(w_man, w_code)[None, :, :]          # (1, G, L)
    fa = frac_int(a_man, a_code)
    shift = (exp_val(w_code)[None, :, :] - emin) + (exp_val(a_code) - emin)
    prod = (w_sign[None, :, :] * a_sign) * fw * fa
    # Intra-group integer MAC (Eq. 7): int32 accumulator, exactly the
    # hardware's LocalACC register.
    p = jnp.sum(prod * jnp.left_shift(jnp.int32(1), shift), axis=2)      # (X_b, G)

    # Group scale unit (Eq. 8): S_p = S_g^w * S_g^a as <E, 2>;
    # integer fraction F in {4, 6, 9} (= {1, 1.5, 2.25} * 4), plus the code
    # sum as the power-of-two exponent. P * F is two shift-adds in hardware
    # (F = 4 + 2*(mw + ma) + mw*ma); here the integer multiply is exact.
    wg = wg_ref[...]                                   # (G, 2): [exp_code, man]
    ag = ag_ref[...]                                   # (G, 2)
    f_scale = 4 + 2 * (wg[:, 1] + ag[:, 1]) + wg[:, 1] * ag[:, 1]        # (G,)
    code_sum = wg[:, 0] + ag[:, 0]                                        # (G,)
    pf = (p * f_scale[None, :]).astype(jnp.float32)
    contrib = pf * jnp.exp2(-code_sum.astype(jnp.float32))[None, :]

    # Inter-group adder tree: the one floating-point accumulation kept.
    fixed_point = jnp.float32(2.0 ** (2 * emin - 2 * cfg.m_x - 2))
    z_ref[...] = jnp.sum(contrib, axis=1) * fixed_point


@functools.partial(jax.jit, static_argnames=("cfg",))
def lowbit_conv_dot(w_fields, a_fields, cfg: QuantConfig):
    """Eq. 6-8 on stored fields. w_fields: dict of (G, L) arrays
    {x_man, x_exp_code, sign, sg_exp_code, sg_man} (group scales (G,));
    a_fields: same with leading X axis for positions, group scales (G,).

    Returns z (X,) -- NOT yet multiplied by S_t^w * S_t^a (the paper defers
    the tensor scale to the next layer, Sec. V-B "can usually be omitted").
    """
    x_pos, g, l = a_fields["x_man"].shape
    xb = 8 if x_pos % 8 == 0 else 1

    wg = jnp.stack([w_fields["sg_exp_code"], w_fields["sg_man"]], axis=1).astype(jnp.int32)
    ag = jnp.stack([a_fields["sg_exp_code"], a_fields["sg_man"]], axis=1).astype(jnp.int32)

    kernel = functools.partial(_lowbit_dot_kernel, cfg=cfg)
    z = pl.pallas_call(
        kernel,
        grid=(x_pos // xb,),
        in_specs=[
            pl.BlockSpec((g, l), lambda i: (0, 0)),
            pl.BlockSpec((g, l), lambda i: (0, 0)),
            pl.BlockSpec((g, l), lambda i: (0, 0)),
            pl.BlockSpec((g, 2), lambda i: (0, 0)),
            pl.BlockSpec((xb, g, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((xb, g, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((xb, g, l), lambda i: (i, 0, 0)),
            pl.BlockSpec((g, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((xb,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((x_pos,), jnp.float32),
        interpret=True,
    )(
        w_fields["x_man"].astype(jnp.int32),
        w_fields["x_exp_code"].astype(jnp.int32),
        w_fields["sign"].astype(jnp.int32),
        wg,
        a_fields["x_man"].astype(jnp.int32),
        a_fields["x_exp_code"].astype(jnp.int32),
        a_fields["sign"].astype(jnp.int32),
        ag,
    )
    return z
