"""AOT compile path: lower every (model, quant-config) step function to HLO
text + emit the artifact manifest and initial-state blobs.

Run once by `make artifacts`; Python never runs on the request path.

Interchange format is HLO **text** (not a serialized HloModuleProto): jax
>= 0.5 emits protos with 64-bit instruction ids that xla_extension 0.5.1
(the version behind the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly. Lowering recipe follows /opt/xla-example/gen_hlo.py:

    lowered = jax.jit(fn).lower(*specs)
    mlir    = lowered.compiler_ir("stablehlo")
    comp    = xc._xla.mlir.mlir_module_to_xla_computation(
                  str(mlir), use_tuple_args=False, return_tuple=True)
    text    = comp.as_hlo_text()

Artifact sets
-------------
  core  (default): the variants used by the quickstart, the e2e example,
        Table II / III and Fig. 6 / 7 — two CNNs x the headline configs.
  full  (--full):  adds the Table IV ablation grid (grouping x M_g x E_x
        x M_x on resnet_t).

Each artifact is accompanied by a manifest entry recording the exact input
and output signature, the flat-state layout, and the quant config, so the
Rust coordinator is fully metadata-driven.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

try:
    from compile.qconfig import QuantConfig, NAMED
    from compile import model as M
except ImportError:  # script-style
    from qconfig import QuantConfig, NAMED  # type: ignore
    import model as M  # type: ignore

BATCH = 32  # training batch size baked into the artifacts


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is ESSENTIAL: the default printer elides big
    # literals as `constant({...})`, which the downstream HLO text parser
    # silently reads back as zeros — e.g. the SGD bn-stat mask vector,
    # which would freeze every parameter update.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # this jaxlib's printer emits source_end_line/... metadata attributes
    # that xla_extension 0.5.1's text parser rejects — drop metadata.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constant survived printing"
    return text


def _sig(shapes_dtypes):
    return [{"name": n, "shape": list(map(int, s)), "dtype": d}
            for n, s, d in shapes_dtypes]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Ablation grid (paper Table IV rows: grouping x M_g x E_x, each x M_x)
# ---------------------------------------------------------------------------

def table4_grid():
    rows = [
        # (#group, M_g, E_x) following Table IV; grouping "none" drops S_g.
        ("none", None, 0),
        ("second", 0, 0),     # grouped by c (2nd dim of activations/weights)
        ("first", 0, 0),      # grouped by n (1st dim)
        ("both", 0, 0),       # n x c
        ("both", 1, 0),
        ("none", None, 1),
        ("none", None, 2),
        ("both", 1, 1),
        ("both", 1, 2),
    ]
    cfgs = []
    for grouping, m_g, e_x in rows:
        for m_x in (4, 3, 2, 1):
            cfgs.append(QuantConfig(
                e_x=e_x, m_x=m_x,
                e_g=8, m_g=(m_g if m_g is not None else 0),
                grouping=grouping,
            ))
    return cfgs


def core_configs():
    return [NAMED[k] for k in ("fp32", "e2m4", "e2m1", "e1m1", "int4", "int2", "e2m3")]


# ---------------------------------------------------------------------------
# Artifact emission
# ---------------------------------------------------------------------------

def emit_model(out_dir: str, model_name: str, cfgs, probes_for, manifest: dict,
               skip_unchanged: bool = True):
    built_meta = None
    for cfg in cfgs:
        store, init, fns, meta = M.build_model(model_name, cfg, BATCH)
        if built_meta is None:
            built_meta = meta
            manifest["models"][model_name] = meta
            init_file = f"{model_name}_init.bin"
            with open(os.path.join(out_dir, init_file), "wb") as f:
                f.write(np.asarray(init, np.float32).tobytes())
            manifest["init"][model_name] = {
                "file": init_file, "dim": int(init.size)}

        sd, b = meta["state_dim"], meta["batch"]
        img = tuple(meta["img_shape"])
        in_train = [
            ("state", (sd,), "f32"), ("images", (b,) + img, "f32"),
            ("labels", (b,), "i32"), ("seed", (), "i32"), ("lr", (), "f32"),
        ]
        out_train = [("state", (sd,), "f32"), ("loss", (), "f32"), ("acc", (), "f32")]
        name = f"{model_name}__{cfg.name()}__train"
        _lower_and_write(
            out_dir, name, fns["train_step"],
            [_spec((sd,)), _spec((b,) + img), _spec((b,), jnp.int32),
             _spec((), jnp.int32), _spec((), jnp.float32)],
            manifest, model_name, cfg, "train_step",
            _sig(in_train), _sig(out_train), skip_unchanged)

        if cfg.name() == "fp32":
            in_eval = [("state", (sd,), "f32"), ("images", (b,) + img, "f32"),
                       ("labels", (b,), "i32")]
            out_eval = [("loss", (), "f32"), ("acc", (), "f32")]
            _lower_and_write(
                out_dir, f"{model_name}__eval", fns["eval_step"],
                [_spec((sd,)), _spec((b,) + img), _spec((b,), jnp.int32)],
                manifest, model_name, cfg, "eval_step",
                _sig(in_eval), _sig(out_eval), skip_unchanged)

        if cfg.name() in probes_for:
            pn = meta["probe_names"]
            outs = (
                [(f"A.{n}", tuple(meta["probe_a_shapes"][n]), "f32") for n in pn]
                + [(f"E.{n}", tuple(meta["probe_e_shapes"][n]), "f32") for n in pn]
                + [(f"W.{n}", tuple(next(s for s in meta["specs"]
                                         if s["name"] == f"{n}.w")["shape"]), "f32")
                   for n in pn]
            )
            in_probe = [("state", (sd,), "f32"), ("images", (b,) + img, "f32"),
                        ("labels", (b,), "i32"), ("seed", (), "i32")]
            _lower_and_write(
                out_dir, f"{model_name}__{cfg.name()}__probe", fns["probe_step"],
                [_spec((sd,)), _spec((b,) + img), _spec((b,), jnp.int32),
                 _spec((), jnp.int32)],
                manifest, model_name, cfg, "probe_step",
                _sig(in_probe), _sig(outs), skip_unchanged)


def _lower_and_write(out_dir, name, fn, specs, manifest, model_name, cfg,
                     fn_kind, inputs, outputs, skip_unchanged):
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    entry = {
        "name": name, "file": f"{name}.hlo.txt", "fn": fn_kind,
        "model": model_name, "cfg": cfg.to_dict(),
        "inputs": inputs, "outputs": outputs,
    }
    manifest["artifacts"].append(entry)
    if skip_unchanged and os.path.exists(path):
        print(f"  [skip] {name}")
        return
    t0 = time.time()
    # keep_unused=True: the fp32 variants ignore `seed`, but the artifact
    # signature must stay identical across configs (the runtime feeds a
    # fixed 5-input train-step contract).
    text = to_hlo_text(jax.jit(fn, keep_unused=True).lower(*specs))
    with open(path, "w") as f:
        f.write(text)
    print(f"  [lower] {name}: {len(text)/1e6:.1f} MB in {time.time()-t0:.1f}s")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--full", action="store_true",
                    help="also emit the Table IV ablation grid")
    ap.add_argument("--quant-impl", default="pallas", choices=["pallas", "ref"])
    ap.add_argument("--models", default="resnet_t,cnn_s")
    args = ap.parse_args()

    M.set_quant_impl(args.quant_impl)
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "golden"), exist_ok=True)

    manifest = {
        "version": 1,
        "batch": BATCH,
        "img_shape": list(M.IMG_SHAPE),
        "num_classes": M.NUM_CLASSES,
        "quant_impl": args.quant_impl,
        "models": {},
        "init": {},
        "artifacts": [],
    }

    models = args.models.split(",")
    for model_name in models:
        print(f"model {model_name}")
        cfgs = core_configs()
        if args.full and model_name == "resnet_t":
            seen = {c.name() for c in cfgs}
            for c in table4_grid():
                if c.name() not in seen:
                    cfgs.append(c)
                    seen.add(c.name())
        probes_for = {NAMED["e2m4"].name()} if model_name == "resnet_t" else set()
        emit_model(out_dir, model_name, cfgs, probes_for, manifest)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {os.path.join(out_dir, 'manifest.json')} "
          f"({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
