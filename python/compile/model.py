"""L2: JAX model zoo + the MLS low-bit training step (paper Alg. 1).

Everything here is build-time only: `aot.py` lowers the jitted functions to
HLO text once, and the Rust coordinator replays the artifacts through PJRT.

Key design points
-----------------
* **Flat state vector.** Parameters, SGD momentum and BN running statistics
  live in ONE f32 vector, so the Rust hot loop moves exactly one state
  literal per step (plus images/labels/seed/lr). The layout is recorded in
  the artifact manifest and reproduced by `rust/src/coordinator/spec.rs`.

* **`mls_conv` is a `jax.custom_vjp`** implementing Alg. 1 exactly:
      forward:   Z = Conv(q(W), q(A))
      backward:  G  = Conv(q(E), q(A))        (weight gradient)
                 dA = Conv^T(q(E), q(W))      (error back-propagation)
  with STE through the quantizers. The rounding-offset tensors R (Alg. 2's
  offline-generated U[-1/2,1/2) noise) are explicit primal inputs derived
  from the per-step seed, so fwd and bwd see the exact noise the paper's
  procedure prescribes and the artifact stays a pure function.

* **Quantization implementation** is selectable (`set_quant_impl`): the
  Pallas kernel (used for all shipped artifacts) or the jnp reference
  (used to cross-check lowering). Both are bit-exact to each other.

* The first conv and the final FC stay unquantized (paper Sec. VI-A), and
  BN / SGD update run in fp32 (paper Sec. III-A).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

try:
    from compile.qconfig import QuantConfig
    from compile.kernels import mls_quant, ref
except ImportError:  # script-style import
    from qconfig import QuantConfig  # type: ignore
    from kernels import mls_quant, ref  # type: ignore

# --------------------------------------------------------------------------
# Quantizer selection (build-time switch; artifacts ship the pallas path)
# --------------------------------------------------------------------------

_QUANT_IMPL = "pallas"


def set_quant_impl(name: str) -> None:
    global _QUANT_IMPL
    if name not in ("pallas", "ref"):
        raise ValueError(name)
    _QUANT_IMPL = name


def _fake_quant(x, cfg: QuantConfig, r):
    if _QUANT_IMPL == "pallas":
        return mls_quant.mls_fake_quant(x, cfg, r)
    return ref.mls_fake_quant(x, cfg, r)


# --------------------------------------------------------------------------
# MLS convolution with the Alg. 1 backward (custom_vjp)
# --------------------------------------------------------------------------


def _conv(w, a, stride, padding):
    return jax.lax.conv_general_dilated(
        a, w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def mls_conv(w, a, r_w, r_a, r_e, cfg: QuantConfig, stride: int, padding: int):
    """Quantized convolution: Z = Conv(q(W), q(A)) with Alg. 1 backward."""
    qw = _fake_quant(w, cfg, r_w)
    qa = _fake_quant(a, cfg, r_a)
    return _conv(qw, qa, stride, padding)


def _mls_conv_fwd(w, a, r_w, r_a, r_e, cfg, stride, padding):
    qw = _fake_quant(w, cfg, r_w)
    qa = _fake_quant(a, cfg, r_a)
    z = _conv(qw, qa, stride, padding)
    return z, (qw, qa, r_e)


def _mls_conv_bwd(cfg, stride, padding, res, e):
    qw, qa, r_e = res
    qe = _fake_quant(e, cfg, r_e)           # quantize the error (Alg. 1 l.12)
    _, vjp = jax.vjp(lambda w_, a_: _conv(w_, a_, stride, padding), qw, qa)
    dw, da = vjp(qe)                        # G = Conv(qE, qA); dA = Conv^T(qE, qW)
    # STE through the quantizers; rounding offsets get zero cotangents.
    return dw, da, jnp.zeros_like(qw), jnp.zeros_like(qa), jnp.zeros_like(qe)


mls_conv.defvjp(_mls_conv_fwd, _mls_conv_bwd)


# --------------------------------------------------------------------------
# Flat-state parameter registry
# --------------------------------------------------------------------------


@dataclass
class VarSpec:
    name: str
    shape: tuple
    kind: str  # "param" | "bn_stat"

    @property
    def size(self) -> int:
        return int(np.prod(self.shape))


@dataclass
class Store:
    """Declaration-order registry of model variables backed by a flat vector.

    Pass 1 (flat=None): records specs and returns numpy initializers.
    Pass 2 (flat=jnp vector): returns slices of the flat vector.
    Updates (BN running stats, SGD results) are collected with `set` and
    re-packed with `pack_updates`.
    """

    flat: object = None
    seed: int = 0
    specs: list = field(default_factory=list)
    offsets: dict = field(default_factory=dict)
    cursor: int = 0
    updates: dict = field(default_factory=dict)
    _rng: object = None

    def __post_init__(self):
        self._rng = np.random.default_rng(self.seed)

    def get(self, name: str, shape: tuple, kind: str = "param", init: str = "zeros"):
        shape = tuple(int(s) for s in shape)
        if name not in self.offsets:
            self.specs.append(VarSpec(name, shape, kind))
            self.offsets[name] = self.cursor
            self.cursor += int(np.prod(shape))
        off = self.offsets[name]
        n = int(np.prod(shape))
        if self.flat is None:
            if init == "zeros":
                return np.zeros(shape, np.float32)
            if init == "ones":
                return np.ones(shape, np.float32)
            if init == "he":
                fan_in = int(np.prod(shape[1:])) if len(shape) > 1 else shape[0]
                std = np.sqrt(2.0 / max(fan_in, 1))
                return (self._rng.normal(0.0, std, size=shape)).astype(np.float32)
            raise ValueError(init)
        return jax.lax.dynamic_slice(self.flat, (off,), (n,)).reshape(shape)

    def set(self, name: str, value) -> None:
        self.updates[name] = value

    def init_vector(self, forward_fn, *fwd_args) -> np.ndarray:
        """Run the shape pass and return the packed initial vector."""
        inits = {}

        real_get = self.get

        def recording_get(name, shape, kind="param", init="zeros"):
            v = real_get(name, shape, kind, init)
            inits[name] = v
            return v

        self.get = recording_get  # type: ignore
        forward_fn(*fwd_args)
        self.get = real_get  # type: ignore
        out = np.zeros(self.cursor, np.float32)
        for spec in self.specs:
            off = self.offsets[spec.name]
            out[off: off + spec.size] = np.asarray(inits[spec.name], np.float32).ravel()
        return out

    def apply_updates(self, flat):
        """Scatter collected updates back into a copy of the flat vector."""
        out = flat
        for name, val in self.updates.items():
            off = self.offsets[name]
            out = jax.lax.dynamic_update_slice(out, val.reshape(-1).astype(jnp.float32), (off,))
        return out

    def manifest(self) -> list:
        return [
            {"name": s.name, "shape": list(s.shape), "kind": s.kind,
             "offset": self.offsets[s.name]}
            for s in self.specs
        ]


# --------------------------------------------------------------------------
# Layers
# --------------------------------------------------------------------------


def _hash_uniform(seed, salt: int, shape):
    """Counter-based uniform noise in [-1/2, 1/2) from (seed, salt, index).

    A murmur3-finalizer hash over an iota keeps the lowered HLO tiny --
    jax.random's threefry added ~100 s of XLA compile time per artifact on
    the PJRT CPU backend (see EXPERIMENTS.md section Perf). The paper only
    requires R ~ U[-1/2, 1/2) "generated offline"; distribution quality of
    a murmur mix is ample for rounding offsets.
    """
    n = int(np.prod(shape)) if shape else 1
    idx = jax.lax.iota(jnp.uint32, max(n, 1))
    h = idx * np.uint32(2654435761)
    h = h + seed.astype(jnp.uint32) * np.uint32(0x9E3779B9)
    h = h + np.uint32((salt * 0x85EBCA6B) & 0xFFFFFFFF)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x85EBCA6B)
    h = h ^ (h >> np.uint32(13))
    h = h * np.uint32(0xC2B2AE35)
    h = h ^ (h >> np.uint32(16))
    u = h.astype(jnp.float32) * np.float32(1.0 / 4294967296.0) - np.float32(0.5)
    return u.reshape(shape)


class Ctx:
    """Per-forward context: quant config, seed for rounding offsets, probe
    taps, BN mode."""

    def __init__(self, store: Store, cfg: QuantConfig, seed, train: bool,
                 taps: dict | None = None, collect: dict | None = None):
        self.store = store
        self.cfg = cfg
        self.seed = seed            # traced int32 scalar (or None: nearest)
        self.train = train
        self.taps = taps            # name -> tensor added to conv output (for E probes)
        self.collect = collect      # dict filled with {"A.<name>": act, ...}
        self.layer_idx = 0

    def next_salts(self, n: int):
        base = self.layer_idx * 16
        self.layer_idx += 1
        return [base + i for i in range(n)]

    def rounding(self, salt: int, shape):
        if self.seed is None or self.cfg.rounding == "nearest" or not self.cfg.enabled:
            return jnp.zeros(shape, jnp.float32)
        return _hash_uniform(self.seed, salt, shape)


def conv2d(ctx: Ctx, name: str, x, cout: int, k: int = 3, stride: int = 1,
           padding: int | None = None, quant: bool = True):
    cin = x.shape[1]
    padding = (k // 2) if padding is None else padding
    w = ctx.store.get(f"{name}.w", (cout, cin, k, k), init="he")
    if quant and ctx.cfg.enabled:
        kw, ka, ke = ctx.next_salts(3)
        out_shape = jax.eval_shape(
            lambda w_, x_: _conv(w_, x_, stride, padding), w, x).shape
        z = mls_conv(
            w, x,
            ctx.rounding(kw, w.shape),
            ctx.rounding(ka, x.shape),
            ctx.rounding(ke, out_shape),
            ctx.cfg, stride, padding,
        )
    else:
        z = _conv(w, x, stride, padding)
    if ctx.collect is not None and quant:
        ctx.collect[f"A.{name}"] = x
    if ctx.taps is not None and quant and f"E.{name}" in ctx.taps:
        z = z + ctx.taps[f"E.{name}"]
    return z


def batchnorm(ctx: Ctx, name: str, x, momentum: float = 0.1, eps: float = 5e-5):
    c = x.shape[1]
    gamma = ctx.store.get(f"{name}.gamma", (c,), init="ones")
    beta = ctx.store.get(f"{name}.beta", (c,))
    run_mean = ctx.store.get(f"{name}.run_mean", (c,), kind="bn_stat")
    run_var = ctx.store.get(f"{name}.run_var", (c,), kind="bn_stat", init="ones")
    if ctx.train:
        mean = jnp.mean(x, axis=(0, 2, 3))
        var = jnp.var(x, axis=(0, 2, 3))
        ctx.store.set(f"{name}.run_mean",
                      (1 - momentum) * run_mean + momentum * jax.lax.stop_gradient(mean))
        ctx.store.set(f"{name}.run_var",
                      (1 - momentum) * run_var + momentum * jax.lax.stop_gradient(var))
    else:
        mean, var = run_mean, run_var
    inv = jax.lax.rsqrt(var + eps)
    return (x - mean[None, :, None, None]) * (gamma * inv)[None, :, None, None] \
        + beta[None, :, None, None]


def fc(ctx: Ctx, name: str, x, dout: int):
    din = x.shape[-1]
    w = ctx.store.get(f"{name}.w", (din, dout), init="he")
    b = ctx.store.get(f"{name}.b", (dout,))
    return x @ w + b


def global_avg_pool(x):
    return jnp.mean(x, axis=(2, 3))


# --------------------------------------------------------------------------
# Model zoo (scaled-down counterparts of the paper's CNNs; see DESIGN.md
# substitution table). Input: NCHW f32, IMG_SHAPE; output: logits (B, 10).
# --------------------------------------------------------------------------

NUM_CLASSES = 10
IMG_SHAPE = (3, 16, 16)


def mlp_forward(ctx: Ctx, x):
    h = x.reshape(x.shape[0], -1)
    h = jax.nn.relu(fc(ctx, "fc1", h, 128))
    h = jax.nn.relu(fc(ctx, "fc2", h, 128))
    return fc(ctx, "head", h, NUM_CLASSES)


def cnn_s_forward(ctx: Ctx, x):
    """VGG-style plain CNN (the paper's VGG-16 analog, scaled)."""
    h = jax.nn.relu(batchnorm(ctx, "bn0", conv2d(ctx, "conv0", x, 16, quant=False)))
    h = jax.nn.relu(batchnorm(ctx, "bn1", conv2d(ctx, "conv1", h, 32, stride=2)))
    h = jax.nn.relu(batchnorm(ctx, "bn2", conv2d(ctx, "conv2", h, 32)))
    h = jax.nn.relu(batchnorm(ctx, "bn3", conv2d(ctx, "conv3", h, 64, stride=2)))
    h = jax.nn.relu(batchnorm(ctx, "bn4", conv2d(ctx, "conv4", h, 64)))
    return fc(ctx, "head", global_avg_pool(h), NUM_CLASSES)


def _basic_block(ctx: Ctx, name: str, x, cout: int, stride: int):
    """ResNet basic block (two 3x3 quantized convs + projection shortcut)."""
    h = jax.nn.relu(batchnorm(ctx, f"{name}.bn1",
                              conv2d(ctx, f"{name}.conv1", x, cout, stride=stride)))
    h = batchnorm(ctx, f"{name}.bn2", conv2d(ctx, f"{name}.conv2", h, cout))
    if stride != 1 or x.shape[1] != cout:
        x = batchnorm(ctx, f"{name}.bns",
                      conv2d(ctx, f"{name}.convs", x, cout, k=1, stride=stride, padding=0))
    return jax.nn.relu(h + x)


def resnet_t_forward(ctx: Ctx, x):
    """3-stage residual CNN (the paper's ResNet-20 analog, scaled)."""
    h = jax.nn.relu(batchnorm(ctx, "bn0", conv2d(ctx, "stem", x, 16, quant=False)))
    h = _basic_block(ctx, "s1b1", h, 16, 1)
    h = _basic_block(ctx, "s2b1", h, 32, 2)
    h = _basic_block(ctx, "s3b1", h, 64, 2)
    return fc(ctx, "head", global_avg_pool(h), NUM_CLASSES)


MODELS = {
    "mlp": mlp_forward,
    "cnn_s": cnn_s_forward,
    "resnet_t": resnet_t_forward,
}


# --------------------------------------------------------------------------
# Loss / steps
# --------------------------------------------------------------------------


def _loss_acc(logits, labels):
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean((jnp.argmax(logits, axis=1) == labels).astype(jnp.float32))
    return loss, acc


def build_model(model: str, cfg: QuantConfig, batch: int, seed: int = 0):
    """Construct the store + step functions for one (model, config) pair.

    Returns (store, init_state, fns) where fns has train_step / eval_step /
    probe_step ready for jit/lowering. State = [params | momentum]; BN
    running stats are 'bn_stat' params updated in the forward pass.
    """
    fwd = MODELS[model]
    store = Store(seed=seed)

    x0 = np.zeros((batch,) + IMG_SHAPE, np.float32)
    ctx0 = Ctx(store, cfg, None, train=True)
    var_init = store.init_vector(lambda x: fwd(ctx0, x), x0)
    n_var = var_init.size

    # momentum buffer appended after the variables
    state_init = np.concatenate([var_init, np.zeros(n_var, np.float32)])

    momentum, weight_decay = 0.9, 5e-4  # paper Sec. VI-A

    def split_state(state):
        return state[:n_var], state[n_var:]

    def train_step(state, images, labels, seed_step, lr):
        """One SGD-with-momentum step of Alg. 1. Returns (state', loss, acc)."""
        var, mom = split_state(state)

        def loss_fn(v):
            s = Store(flat=v)
            s.specs, s.offsets, s.cursor = store.specs, store.offsets, store.cursor
            ctx = Ctx(s, cfg, seed_step, train=True)
            logits = fwd(ctx, images)
            loss, acc = _loss_acc(logits, labels)
            # aux must be a pytree (dict of arrays), not the Store object
            return loss, (acc, s.updates)

        (loss, (acc, updates)), grads = jax.value_and_grad(loss_fn, has_aux=True)(var)
        # BN running stats are data updates, not gradient updates.
        var_bn = var
        for uname, uval in updates.items():
            off = store.offsets[uname]
            var_bn = jax.lax.dynamic_update_slice(
                var_bn, uval.reshape(-1).astype(jnp.float32), (off,))
        # zero the gradient of bn_stat slots (they are not trained)
        mask = np.ones(n_var, np.float32)
        for spec in store.specs:
            if spec.kind == "bn_stat":
                off = store.offsets[spec.name]
                mask[off: off + spec.size] = 0.0
        g = grads * mask + weight_decay * var_bn * mask
        new_mom = momentum * mom + g
        new_var = var_bn - lr * new_mom
        new_state = jnp.concatenate([new_var, new_mom])
        return new_state, loss, acc

    def eval_step(state, images, labels):
        """Eval with running BN stats; quantization disabled (the learned
        float weights are evaluated at full precision, as in the paper)."""
        var, _ = split_state(state)
        s = Store(flat=var)
        s.specs, s.offsets, s.cursor = store.specs, store.offsets, store.cursor
        ctx = Ctx(s, QuantConfig(enabled=False), None, train=False)
        logits = fwd(ctx, images)
        loss, acc = _loss_acc(logits, labels)
        return loss, acc

    # names of quantized convs, declaration order (for probes)
    probe_names = [s.name[:-2] for s in store.specs
                   if s.name.endswith(".w") and len(s.shape) == 4
                   and s.name not in ("conv0.w", "stem.w")]

    # Static shapes of conv inputs (A) and outputs (E taps), recorded once
    # at build time with an abstract forward pass.
    a_shapes, tap_shapes = {}, {}

    def _shape_pass(var, images):
        s = Store(flat=var)
        s.specs, s.offsets, s.cursor = store.specs, store.offsets, store.cursor
        collect = {}
        ctx = Ctx(s, cfg, None, train=True, collect=collect)
        fwd(ctx, images)
        return collect

    collected = jax.eval_shape(_shape_pass,
                               jax.ShapeDtypeStruct((n_var,), jnp.float32),
                               jax.ShapeDtypeStruct((batch,) + IMG_SHAPE, jnp.float32))
    for name in probe_names:
        a_shapes[name] = tuple(collected[f"A.{name}"].shape)
        spec = next(sp for sp in store.specs if sp.name == f"{name}.w")
        stride = _STRIDES.get((model, name), 1)
        pad = spec.shape[2] // 2
        z = jax.eval_shape(
            lambda w_, a_, s_=stride, p_=pad: _conv(w_, a_, s_, p_),
            jax.ShapeDtypeStruct(spec.shape, jnp.float32),
            jax.ShapeDtypeStruct(a_shapes[name], jnp.float32))
        tap_shapes[name] = tuple(z.shape)

    def probe_step(state, images, labels, seed_step):
        """Capture per-layer A (conv inputs), E (conv-output errors) and W
        for Fig. 6 / Fig. 7. Returns tuple(A_1..A_k, E_1..E_k, W_1..W_k)."""
        var, _ = split_state(state)

        def reader():
            s = Store(flat=var)
            s.specs, s.offsets, s.cursor = store.specs, store.offsets, store.cursor
            return s

        def loss_with_taps(taps):
            c = Ctx(reader(), cfg, seed_step, train=True, taps=taps, collect={})
            lg = fwd(c, images)
            loss, _ = _loss_acc(lg, labels)
            return loss, c.collect

        taps0 = {f"E.{n}": jnp.zeros(tap_shapes[n], jnp.float32) for n in probe_names}
        (_loss, acts), gtaps = jax.value_and_grad(loss_with_taps, has_aux=True)(taps0)

        outs = [acts[f"A.{n}"] for n in probe_names]
        outs += [gtaps[f"E.{n}"] for n in probe_names]
        s = reader()
        for n in probe_names:
            spec = next(sp for sp in store.specs if sp.name == f"{n}.w")
            outs.append(s.get(f"{n}.w", spec.shape))
        return tuple(outs)

    fns = {
        "train_step": train_step,
        "eval_step": eval_step,
        "probe_step": probe_step,
    }
    meta = {
        "model": model,
        "n_var": int(n_var),
        "state_dim": int(state_init.size),
        "batch": int(batch),
        "img_shape": list(IMG_SHAPE),
        "num_classes": NUM_CLASSES,
        "probe_names": probe_names,
        "probe_a_shapes": {n: list(a_shapes[n]) for n in probe_names},
        "probe_e_shapes": {n: list(tap_shapes[n]) for n in probe_names},
        "specs": store.manifest(),
    }
    return store, state_init, fns, meta


# static stride table for probe-shape recovery (model, conv-name) -> stride
_STRIDES = {
    ("cnn_s", "conv1"): 2,
    ("cnn_s", "conv3"): 2,
    ("resnet_t", "s2b1.conv1"): 2,
    ("resnet_t", "s2b1.convs"): 2,
    ("resnet_t", "s3b1.conv1"): 2,
    ("resnet_t", "s3b1.convs"): 2,
}
