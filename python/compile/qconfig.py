"""Quantization configuration for the MLS (multi-level scaling) tensor format.

This mirrors the paper's ablation axes (Table IV):
  - element format  <E_x, M_x>   (element-wise exponent + mantissa, no sign bit)
  - group format    <E_g, M_g>   (hardware-friendly group scale, M_g in {0, 1})
  - grouping dims   none | first | second | both  (paper: 1 / c or co / n / nc)
  - rounding        stochastic (paper default, Alg. 2) | nearest

The same field names and semantics are used by the Rust coordinator
(rust/src/mls/) and by the artifact manifest, so a config round-trips
unchanged across the three layers.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass

GROUPINGS = ("none", "first", "second", "both")
ROUNDINGS = ("stochastic", "nearest")


@dataclass(frozen=True)
class QuantConfig:
    """Configuration of one MLS quantizer (applied to W, A and E alike).

    The paper uses the same bit-width for weight / activation / error
    ("we adopt the same quantization bit-width for weight, activation and
    error for a simpler hardware design", Sec. VI-A), so one config object
    describes all three operand quantizers. ``enabled`` turns the whole
    quantization off (fp32 baseline).
    """

    e_x: int = 2          # element exponent bits  (paper: 2)
    m_x: int = 4          # element mantissa bits  (paper: 4 on ImageNet, 1 on CIFAR)
    e_g: int = 8          # group-scale exponent bits (paper: 8)
    m_g: int = 1          # group-scale mantissa bits (paper: 1; 0 = power of two)
    grouping: str = "both"  # "none" | "first" | "second" | "both"
    rounding: str = "stochastic"
    enabled: bool = True

    def __post_init__(self) -> None:
        if self.grouping not in GROUPINGS:
            raise ValueError(f"grouping must be one of {GROUPINGS}, got {self.grouping!r}")
        if self.rounding not in ROUNDINGS:
            raise ValueError(f"rounding must be one of {ROUNDINGS}, got {self.rounding!r}")
        if not (0 <= self.e_x <= 8):
            raise ValueError(f"e_x out of range [0, 8]: {self.e_x}")
        if not (0 <= self.m_x <= 23):
            raise ValueError(f"m_x out of range [0, 23]: {self.m_x}")
        if not (0 <= self.e_g <= 8):
            raise ValueError(f"e_g out of range [0, 8]: {self.e_g}")
        if self.m_g not in (0, 1):
            # The hardware group-scale unit (Eq. 8) only supports <E_g, 0>
            # (pure shift) and <E_g, 1> (shift + shifted add).
            raise ValueError(f"m_g must be 0 or 1 (hardware shift-add unit), got {self.m_g}")

    # -- derived quantities used by the bit-width analysis (Sec. V-C) -----
    @property
    def product_bits(self) -> int:
        """Bit-width of one element x element product: 2M + 2^(E+1) - 2."""
        return 2 * self.m_x + 2 ** (self.e_x + 1) - 2

    @property
    def accumulator_bits(self) -> int:
        """Smallest power-of-two-width integer accumulator that holds the
        intra-group partial sums: product bits + 4 bits of K*K=9
        accumulation headroom (paper Table II: 8 for <1,1>, 16 for <2,1>,
        32 for <2,4>). Mirrored by rust QuantConfig::accumulator_bits."""
        for w in (8, 16, 32, 64):
            if self.product_bits + 4 <= w:
                return w
        return 64

    @property
    def element_bits(self) -> int:
        """Stored bits per element: sign + exponent code + mantissa."""
        return 1 + self.e_x + self.m_x

    def name(self) -> str:
        """Stable short name used in artifact file names and manifests."""
        if not self.enabled:
            return "fp32"
        g = {"none": "g1", "first": "gf", "second": "gs", "both": "gnc"}[self.grouping]
        r = "sr" if self.rounding == "stochastic" else "nr"
        return f"e{self.e_x}m{self.m_x}_{g}_eg{self.e_g}mg{self.m_g}_{r}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: dict) -> "QuantConfig":
        return QuantConfig(**d)


# Named configs referenced throughout the repo (tables, artifacts, tests).
FP32 = QuantConfig(enabled=False)
# Paper's ImageNet headline config: <2,4> elements, <8,1> group scale, n x c groups.
E2M4 = QuantConfig(e_x=2, m_x=4)
# Paper's CIFAR headline config: <2,1> elements.
E2M1 = QuantConfig(e_x=2, m_x=1)
# <1,1> row of Table II (VGG-16, 8-bit accumulation).
E1M1 = QuantConfig(e_x=1, m_x=1)
# Fixed-point rows of Table II / IV ("single number" = M_x bits, E_x = 0).
INT4 = QuantConfig(e_x=0, m_x=4)
INT2 = QuantConfig(e_x=0, m_x=2)
# 6-bit sensitivity config of Table III (<2,3> is 6 stored bits: 1+2+3).
E2M3 = QuantConfig(e_x=2, m_x=3)

NAMED = {
    "fp32": FP32,
    "e2m4": E2M4,
    "e2m1": E2M1,
    "e1m1": E1M1,
    "int4": INT4,
    "int2": INT2,
    "e2m3": E2M3,
}
