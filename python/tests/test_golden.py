"""Golden-vector emission for the Rust bit-accurate MLS implementation.

Writes artifacts/golden/*.json; `cargo test --test golden` parses these and
must reproduce every stored field BIT-EXACTLY (same IEEE-754 decomposition,
same round-half-up, same clip/carry behaviour). The test here re-checks
self-consistency so a stale golden never silently passes.
"""

import dataclasses
import json
import os

import numpy as np
import pytest

pytest.importorskip("jax", reason="XLA-dependent: golden emission needs jax")
import jax.numpy as jnp

from compile.qconfig import QuantConfig, NAMED
from compile.kernels import ref

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "golden")

CASES = [
    ("e2m4_nc", NAMED["e2m4"], (3, 4, 3, 3), 0),
    ("e2m1_nc", NAMED["e2m1"], (3, 4, 3, 3), 1),
    ("e1m1", NAMED["e1m1"], (2, 5, 2, 2), 2),
    ("int4", NAMED["int4"], (3, 4, 3, 3), 3),
    ("int2", NAMED["int2"], (4, 2, 3, 3), 4),
    ("e2m3_first", dataclasses.replace(NAMED["e2m3"], grouping="first"), (4, 3, 2, 2), 5),
    ("e2m4_second", dataclasses.replace(NAMED["e2m4"], grouping="second"), (4, 3, 2, 2), 6),
    ("e2m4_none", dataclasses.replace(NAMED["e2m4"], grouping="none"), (3, 3, 2, 2), 7),
    ("e2m4_mg0", dataclasses.replace(NAMED["e2m4"], m_g=0), (3, 4, 3, 3), 8),
    ("e2m4_nearest", dataclasses.replace(NAMED["e2m4"], rounding="nearest"), (3, 4, 3, 3), 9),
    ("e4m3_wide", QuantConfig(e_x=4, m_x=3), (3, 3, 3, 3), 10),
    ("e2m4_eg4", dataclasses.replace(NAMED["e2m4"], e_g=4), (3, 4, 3, 3), 11),
]


def _make_input(shape, seed):
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=shape) * np.exp(rng.normal(size=shape[:2] + (1, 1)) * 2)).astype(np.float32)
    # sprinkle exact zeros, powers of two, denormal-feeders
    flat = x.reshape(-1)
    flat[:: max(len(flat) // 7, 1)] = 0.0
    flat[1:: max(len(flat) // 5, 1)] *= 1e-30
    r = rng.uniform(-0.5, 0.5, shape).astype(np.float32)
    return x, r


def test_emit_goldens():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    index = []
    for name, cfg, shape, seed in CASES:
        x, r = _make_input(shape, seed)
        fields = {k: np.asarray(v)
                  for k, v in ref.mls_quantize_fields(jnp.asarray(x), cfg, jnp.asarray(r)).items()}
        # self-consistency: q == fake_quant
        q2 = np.asarray(ref.mls_fake_quant(jnp.asarray(x), cfg, jnp.asarray(r)))
        np.testing.assert_array_equal(fields["q"], q2)
        # ARE as an extra scalar the rust side reproduces
        are = float(ref.average_relative_error(jnp.asarray(x), cfg))
        doc = {
            "name": name,
            "cfg": cfg.to_dict(),
            "shape": list(shape),
            "x": [float(v) for v in x.reshape(-1)],
            "r": [float(v) for v in r.reshape(-1)],
            "q": [float(v) for v in fields["q"].reshape(-1)],
            "s_t": float(fields["s_t"]),
            "s_g": [float(v) for v in fields["s_g"].reshape(-1)],
            "sg_exp_code": [int(v) for v in fields["sg_exp_code"].reshape(-1)],
            "sg_man": [int(v) for v in fields["sg_man"].reshape(-1)],
            "x_exp_code": [int(v) for v in fields["x_exp_code"].reshape(-1)],
            "x_man": [int(v) for v in fields["x_man"].reshape(-1)],
            "sign": [int(v) for v in fields["sign"].reshape(-1)],
            "are_nearest": are,
        }
        path = os.path.join(GOLDEN_DIR, f"{name}.json")
        with open(path, "w") as f:
            json.dump(doc, f)
        index.append(f"{name}.json")
    with open(os.path.join(GOLDEN_DIR, "index.json"), "w") as f:
        json.dump(index, f)
    assert len(index) == len(CASES)


def test_emit_mac_golden():
    """Golden for the integer intra-group MAC (rust/src/arith)."""
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    cfg = NAMED["e2m4"]
    rng = np.random.default_rng(42)
    g, l = 6, 9
    w = (rng.normal(size=(g, l)) * np.exp(rng.normal(size=(g, 1)))).astype(np.float32)
    a = (rng.normal(size=(g, l)) * np.exp(rng.normal(size=(g, 1)))).astype(np.float32)
    wcfg = dataclasses.replace(cfg, grouping="first", rounding="nearest")
    wf = {k: np.asarray(v) for k, v in ref.mls_quantize_fields(jnp.asarray(w), wcfg).items()}
    af = {k: np.asarray(v) for k, v in ref.mls_quantize_fields(jnp.asarray(a), wcfg).items()}
    p, scale_log2 = ref.intra_group_mac_ref(
        {"x_man": wf["x_man"], "x_exp_code": wf["x_exp_code"], "sign": wf["sign"]},
        {"x_man": af["x_man"], "x_exp_code": af["x_exp_code"], "sign": af["sign"]},
        cfg.e_x, cfg.m_x)
    doc = {
        "cfg": cfg.to_dict(),
        "g": g, "l": l,
        "w": [float(v) for v in w.reshape(-1)],
        "a": [float(v) for v in a.reshape(-1)],
        "w_q": [float(v) for v in wf["q"].reshape(-1)],
        "a_q": [float(v) for v in af["q"].reshape(-1)],
        "w_st": float(wf["s_t"]), "a_st": float(af["s_t"]),
        "w_sg": [float(v) for v in wf["s_g"].reshape(-1)],
        "a_sg": [float(v) for v in af["s_g"].reshape(-1)],
        "p": [int(v) for v in np.asarray(p).reshape(-1)],
        "scale_log2": int(scale_log2),
    }
    with open(os.path.join(GOLDEN_DIR, "mac_e2m4.json"), "w") as f:
        json.dump(doc, f)
