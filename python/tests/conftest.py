import os
import sys

# Make `compile.*` importable whether pytest runs from python/ or the repo root.
_HERE = os.path.dirname(os.path.abspath(__file__))
_PY = os.path.dirname(_HERE)
if _PY not in sys.path:
    sys.path.insert(0, _PY)
