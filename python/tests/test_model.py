"""L2 model tests: shapes, training dynamics, STE backward, state packing."""

import numpy as np
import pytest

pytest.importorskip("jax", reason="XLA-dependent: L2 models need jax")
import jax
import jax.numpy as jnp

from compile.qconfig import QuantConfig, E2M4, FP32
from compile import model as M


@pytest.fixture(autouse=True)
def _ref_impl():
    # ref impl traces ~4x faster; pallas/ref bit-exactness is covered by
    # test_kernel.py, and test_pallas_impl_matches below double-checks here.
    M.set_quant_impl("ref")
    yield
    M.set_quant_impl("pallas")


def _data(seed, batch=8):
    rng = np.random.default_rng(seed)
    temps = rng.normal(size=(10, 3, 16, 16)).astype(np.float32)
    y = rng.integers(0, 10, batch)
    x = temps[y] + 0.3 * rng.normal(size=(batch, 3, 16, 16))
    return jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.int32)


@pytest.mark.parametrize("model", ["mlp", "cnn_s", "resnet_t"])
def test_build_and_shapes(model):
    store, init, fns, meta = M.build_model(model, E2M4, 8)
    assert init.shape == (meta["state_dim"],)
    assert meta["state_dim"] == 2 * meta["n_var"]
    x, y = _data(0)
    state, loss, acc = jax.jit(fns["train_step"])(
        jnp.asarray(init), x, y, jnp.int32(0), jnp.float32(0.01))
    assert state.shape == (meta["state_dim"],)
    assert np.isfinite(float(loss)) and 0.0 <= float(acc) <= 1.0


@pytest.mark.parametrize("model", ["cnn_s", "resnet_t"])
@pytest.mark.parametrize("cfg", [FP32, E2M4])
def test_loss_decreases(model, cfg):
    store, init, fns, meta = M.build_model(model, cfg, 8)
    ts = jax.jit(fns["train_step"])
    state = jnp.asarray(init)
    x, y = _data(1)
    losses = []
    for i in range(12):
        state, loss, _ = ts(state, x, y, jnp.int32(i), jnp.float32(0.05))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.7, losses


def test_eval_uses_running_stats():
    store, init, fns, meta = M.build_model("cnn_s", FP32, 8)
    x, y = _data(2)
    # untouched init state: running stats are (0, 1); eval must be finite
    loss, acc = jax.jit(fns["eval_step"])(jnp.asarray(init), x, y)
    assert np.isfinite(float(loss))


def test_bn_stats_updated():
    store, init, fns, meta = M.build_model("cnn_s", FP32, 8)
    x, y = _data(3)
    state, *_ = jax.jit(fns["train_step"])(
        jnp.asarray(init), x, y, jnp.int32(0), jnp.float32(0.0))
    spec = next(s for s in meta["specs"] if s["name"] == "bn1.run_mean")
    off, n = spec["offset"], int(np.prod(spec["shape"]))
    before = np.asarray(init)[off:off + n]
    after = np.asarray(state)[off:off + n]
    assert not np.allclose(before, after)


def test_zero_lr_keeps_params():
    """With lr=0 only BN stats may change."""
    store, init, fns, meta = M.build_model("resnet_t", E2M4, 8)
    x, y = _data(4)
    state, *_ = jax.jit(fns["train_step"])(
        jnp.asarray(init), x, y, jnp.int32(0), jnp.float32(0.0))
    after = np.asarray(state)
    for s in meta["specs"]:
        if s["kind"] == "param":
            off, n = s["offset"], int(np.prod(s["shape"]))
            np.testing.assert_array_equal(after[off:off + n],
                                          np.asarray(init)[off:off + n], err_msg=s["name"])


def test_mls_conv_ste_gradients():
    """Alg. 1 backward: dW == Conv(qE, qA), dA == Conv^T(qE, qW)."""
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(size=(4, 3, 3, 3)), jnp.float32)
    a = jnp.asarray(rng.normal(size=(2, 3, 8, 8)), jnp.float32)
    cfg = QuantConfig(rounding="nearest")
    zeros = lambda t: jnp.zeros_like(t)
    out_shape = jax.eval_shape(lambda w_, a_: M._conv(w_, a_, 1, 1), w, a).shape
    re = jnp.zeros(out_shape, jnp.float32)

    def f(w_, a_):
        return jnp.sum(M.mls_conv(w_, a_, zeros(w_), zeros(a_), re, cfg, 1, 1))

    dw, da = jax.grad(f, argnums=(0, 1))(w, a)
    # manual: e = ones; qe = quant(ones); dw = conv_vjp at (qw, qa)
    from compile.kernels import ref
    qw = ref.mls_fake_quant(w, cfg)
    qa = ref.mls_fake_quant(a, cfg)
    e = jnp.ones(out_shape, jnp.float32)
    qe = ref.mls_fake_quant(e, cfg)
    _, vjp = jax.vjp(lambda w_, a_: M._conv(w_, a_, 1, 1), qw, qa)
    dw_ref, da_ref = vjp(qe)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(da), np.asarray(da_ref), rtol=1e-5, atol=1e-5)


def test_fp32_conv_path_has_no_quant():
    """FP32 config must reduce mls paths to the plain convolution."""
    rng = np.random.default_rng(6)
    store, init, fns, _ = M.build_model("cnn_s", FP32, 4)
    x, y = _data(7, batch=4)
    s1, l1, _ = jax.jit(fns["train_step"])(jnp.asarray(init), x, y, jnp.int32(0), jnp.float32(0.01))
    s2, l2, _ = jax.jit(fns["train_step"])(jnp.asarray(init), x, y, jnp.int32(99), jnp.float32(0.01))
    # seed must not matter without quantization noise
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))


def test_seed_changes_stochastic_rounding():
    store, init, fns, _ = M.build_model("cnn_s", E2M4, 4)
    x, y = _data(8, batch=4)
    s1, *_ = jax.jit(fns["train_step"])(jnp.asarray(init), x, y, jnp.int32(0), jnp.float32(0.01))
    s2, *_ = jax.jit(fns["train_step"])(jnp.asarray(init), x, y, jnp.int32(1), jnp.float32(0.01))
    assert not np.array_equal(np.asarray(s1), np.asarray(s2))


def test_probe_step_shapes():
    store, init, fns, meta = M.build_model("resnet_t", E2M4, 4)
    x, y = _data(9, batch=4)
    outs = jax.jit(fns["probe_step"])(jnp.asarray(init), x, y, jnp.int32(0))
    k = len(meta["probe_names"])
    assert len(outs) == 3 * k
    for i, n in enumerate(meta["probe_names"]):
        assert tuple(outs[i].shape) == tuple(meta["probe_a_shapes"][n])
        assert tuple(outs[k + i].shape) == tuple(meta["probe_e_shapes"][n])
    # errors must be non-trivial
    assert any(float(jnp.abs(outs[k + i]).max()) > 0 for i in range(k))


def test_probe_error_is_gradient():
    """The E tap of the LAST quantized conv must equal the true gradient of
    the loss w.r.t. that conv's output (chain rule sanity)."""
    store, init, fns, meta = M.build_model("cnn_s", QuantConfig(enabled=False), 4)
    x, y = _data(10, batch=4)
    outs = fns["probe_step"](jnp.asarray(init), x, y, jnp.int32(0))
    k = len(meta["probe_names"])
    e_taps = {n: outs[k + i] for i, n in enumerate(meta["probe_names"])}
    assert all(np.isfinite(np.asarray(v)).all() for v in e_taps.values())


def test_hash_uniform_range_and_determinism():
    u1 = np.asarray(M._hash_uniform(jnp.int32(7), 3, (1000,)))
    u2 = np.asarray(M._hash_uniform(jnp.int32(7), 3, (1000,)))
    u3 = np.asarray(M._hash_uniform(jnp.int32(8), 3, (1000,)))
    np.testing.assert_array_equal(u1, u2)
    assert not np.array_equal(u1, u3)
    assert u1.min() >= -0.5 and u1.max() < 0.5
    assert abs(u1.mean()) < 0.05


def test_pallas_impl_matches_ref_in_train_step():
    x, y = _data(11, batch=4)
    states = {}
    for impl in ("ref", "pallas"):
        M.set_quant_impl(impl)
        store, init, fns, _ = M.build_model("cnn_s", E2M4, 4)
        s, loss, _ = jax.jit(fns["train_step"])(
            jnp.asarray(init), x, y, jnp.int32(3), jnp.float32(0.02))
        states[impl] = np.asarray(s)
    np.testing.assert_array_equal(states["ref"], states["pallas"])
