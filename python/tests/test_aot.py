"""AOT path tests: config registry, HLO-text emission, manifest integrity."""

import json
import os

import pytest

pytest.importorskip("jax", reason="XLA-dependent: AOT lowering needs jax")
import jax
import jax.numpy as jnp

from compile import aot
from compile import model as M
from compile.qconfig import NAMED, QuantConfig


def test_table4_grid_matches_paper_rows():
    cfgs = aot.table4_grid()
    # 9 rows x 4 mantissa widths
    assert len(cfgs) == 36
    names = {c.name() for c in cfgs}
    assert len(names) == 36, "grid configs must be distinct"
    # the paper's headline ablation cells exist
    assert QuantConfig(e_x=0, m_x=1, grouping="both", m_g=1).name() in names
    assert QuantConfig(e_x=2, m_x=1, grouping="both", m_g=1).name() in names
    assert QuantConfig(e_x=2, m_x=4, grouping="none", m_g=0).name() in names


def test_core_configs_unique_and_named():
    cfgs = aot.core_configs()
    assert cfgs[0].name() == "fp32"
    assert len({c.name() for c in cfgs}) == len(cfgs)


def test_hlo_text_emission_smoke():
    """Lower the cheapest model and verify the HLO text parses as HLO."""
    M.set_quant_impl("ref")
    try:
        store, init, fns, meta = M.build_model("mlp", NAMED["fp32"], 4)
        sd, b = meta["state_dim"], meta["batch"]
        text = aot.to_hlo_text(jax.jit(fns["eval_step"]).lower(
            aot._spec((sd,)), aot._spec((b, 3, 16, 16)), aot._spec((b,), jnp.int32)))
        assert text.startswith("HloModule")
        assert "ENTRY" in text
    finally:
        M.set_quant_impl("pallas")


def test_manifest_exists_and_is_consistent():
    """After `make artifacts`, the manifest must describe real files."""
    adir = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    mpath = os.path.join(adir, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("run `make artifacts` first")
    m = json.load(open(mpath))
    assert m["artifacts"], "no artifacts listed"
    for a in m["artifacts"]:
        path = os.path.join(adir, a["file"])
        assert os.path.exists(path), f"missing {a['file']}"
        # config round-trips through its name
        cfg = QuantConfig.from_dict(a["cfg"])
        assert cfg.name() in a["file"] or not cfg.enabled
    for name, meta in m["models"].items():
        init = os.path.join(adir, m["init"][name]["file"])
        assert os.path.getsize(init) == meta["state_dim"] * 4
        # spec layout tiles [0, n_var)
        specs = sorted(meta["specs"], key=lambda s: s["offset"])
        cursor = 0
        for s in specs:
            assert s["offset"] == cursor, s
            size = 1
            for d in s["shape"]:
                size *= d
            cursor += size
        assert cursor == meta["n_var"]
