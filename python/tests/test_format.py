"""Unit tests for the canonical <E, M> format numerics in kernels/ref.py.

These pin down the bit-level behaviour the whole repo depends on: exponent
ranges, gradual underflow, saturation, group-scale ceil/carry/dominance.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="XLA-dependent: ref numerics need jax")
import jax.numpy as jnp

from compile.qconfig import QuantConfig
from compile.kernels import ref


def q_elem(x, e, m, r=None):
    x = np.asarray(x, np.float32)
    rr = np.zeros_like(x) if r is None else np.asarray(r, np.float32)
    return np.asarray(ref.quantize_element(jnp.asarray(x), e, m, jnp.asarray(rr)))


class TestF32Fields:
    def test_exponent_of_powers(self):
        x = np.array([1.0, 2.0, 0.5, 0.25, 4.0], np.float32)
        assert list(np.asarray(ref.f32_exponent(jnp.asarray(x)))) == [0, 1, -1, -2, 2]

    def test_fraction(self):
        x = np.array([1.5, 3.0, 0.75], np.float32)
        np.testing.assert_allclose(np.asarray(ref.f32_fraction(jnp.asarray(x))), [1.5, 1.5, 1.5])

    def test_zero_maps_below_any_emin(self):
        assert int(np.asarray(ref.f32_exponent(jnp.asarray(np.float32(0.0))))) == -127


class TestElementQuantization:
    def test_exact_values_survive(self):
        # representable <2,2> values: exp in {-1,-2,-3}, man in {0..3}
        for exp in (-1, -2, -3):
            for man in range(4):
                v = (1 + man / 4.0) * 2.0 ** exp
                assert q_elem(v, 2, 2) == np.float32(v), (exp, man)

    def test_max_representable_saturation(self):
        # xf == 1.0 (the group max) saturates to (2 - 2^-M) / 2
        for m in (1, 2, 4):
            expect = (2.0 - 2.0 ** -m) / 2.0
            assert q_elem(1.0, 2, m) == np.float32(expect)

    def test_subnormal_level(self):
        # <2,2>: emin = -3; subnormals are man/4 * 2^-3, man in 0..3
        e, m = 2, 2
        emin = 1 - 2 ** e
        for man in range(4):
            v = man / 4.0 * 2.0 ** emin
            assert q_elem(v, e, m) == np.float32(v)

    def test_underflow_to_zero(self):
        # below half the smallest subnormal step -> rounds to 0
        e, m = 2, 2
        tiny = 0.2 * 2.0 ** (1 - 2 ** e) / 2 ** m
        assert q_elem(tiny, e, m) == 0.0

    def test_zero(self):
        assert q_elem(0.0, 2, 4) == 0.0

    def test_nearest_rounding_half_up(self):
        # value halfway between man=0 and man=1 at exp=-1 rounds up
        e, m = 2, 2
        v = (1 + 0.5 / 4.0) * 0.5  # man_f = 0.5 -> floor(0.5+0.5)=1
        assert q_elem(v, e, m) == np.float32((1 + 1 / 4.0) * 0.5)

    def test_stochastic_rounding_bounds(self):
        rng = np.random.default_rng(0)
        x = rng.uniform(0, 1, 512).astype(np.float32)
        r = rng.uniform(-0.5, 0.5, 512).astype(np.float32)
        q = q_elem(x, 2, 3, r)
        # stochastic result is one of the two neighbours of the nearest grid
        qn = q_elem(x, 2, 3)
        step = 2.0 ** -3 * 2.0 ** -1  # largest grid step (exp=-1)
        assert np.all(np.abs(q - qn) <= step + 1e-7)

    def test_stochastic_rounding_unbiased(self):
        rng = np.random.default_rng(1)
        x = np.full(20000, 0.6, np.float32)
        r = rng.uniform(-0.5, 0.5, 20000).astype(np.float32)
        q = q_elem(x, 2, 2, r)
        assert abs(float(q.mean()) - 0.6) < 2e-3

    def test_monotonic(self):
        x = np.sort(np.random.default_rng(2).uniform(0, 1, 256).astype(np.float32))
        q = q_elem(x, 2, 3)
        assert np.all(np.diff(q) >= 0)

    def test_e0_is_fixed_point(self):
        # E=0: emin = 0 -- every value < 1 underflows to man/2^M (plain
        # fixed point), matching the paper's "single number" rows.
        x = np.array([0.3, 0.7, 0.99], np.float32)
        q = q_elem(x, 0, 4)
        np.testing.assert_allclose(
            q, np.minimum(np.floor(x * 16 + 0.5), 15) / 16, atol=1e-7)


class TestGroupScale:
    def qg(self, s, e, m):
        return float(np.asarray(ref.quantize_group_scale(jnp.asarray(np.float32(s)), e, m)))

    def test_dominance(self):
        rng = np.random.default_rng(3)
        s = rng.uniform(0, 1, 1024).astype(np.float32)
        sg = np.asarray(ref.quantize_group_scale(jnp.asarray(s), 8, 1))
        assert np.all(sg >= s - 1e-7)

    def test_max_group_is_one(self):
        assert self.qg(1.0, 8, 1) == 1.0

    def test_power_of_two_format(self):
        # <E,0>: result is the next power of two >= s
        for s in (0.3, 0.5, 0.6, 0.9):
            got = self.qg(s, 8, 0)
            assert got >= s and np.log2(got) == np.floor(np.log2(got))

    def test_eg1_shift_add_values(self):
        # <E,1>: fractions are 1 or 1.5 (Eq. 4)
        for s in (0.26, 0.3, 0.4, 0.55, 0.8):
            got = self.qg(s, 8, 1)
            frac = got / 2.0 ** np.floor(np.log2(got))
            assert frac in (1.0, 1.5), (s, got, frac)

    def test_ceil_carry(self):
        # s slightly above 1.5 * 2^-1 must carry to 1.0 (frac 2.0 -> exp+1)
        assert self.qg(0.76, 8, 1) == 1.0

    def test_zero_group_pinned(self):
        got = self.qg(0.0, 8, 1)
        assert got == 2.0 ** -126  # pinned normal-f32 floor (DESIGN.md)

    def test_codes_roundtrip(self):
        rng = np.random.default_rng(4)
        s = rng.uniform(0.001, 1.0, 256).astype(np.float32)
        code, man = map(np.asarray, ref.group_scale_codes(jnp.asarray(s), 8, 1))
        sg = np.asarray(ref.quantize_group_scale(jnp.asarray(s), 8, 1))
        rebuilt = (1 + man / 2.0) * 2.0 ** (-code.astype(np.float64))
        np.testing.assert_allclose(rebuilt, sg, rtol=1e-6)


class TestFakeQuant:
    def test_error_bound_nearest(self):
        # |q - x| <= S_t * S_g * 2^{-1} / 2^M  (half ulp at the top level)
        rng = np.random.default_rng(5)
        x = rng.normal(size=(4, 8, 3, 3)).astype(np.float32)
        cfg = QuantConfig(e_x=2, m_x=4, rounding="nearest")
        f = {k: np.asarray(v) for k, v in ref.mls_quantize_fields(x, cfg).items()}
        bound = float(f["s_t"]) * f["s_g"] * 0.5 * 2.0 ** -4
        assert np.all(np.abs(f["q"] - x) <= bound + 1e-7)

    def test_requantization_is_contraction(self):
        # True idempotence does not hold (the saturated max element shifts
        # S_t on the second pass), but re-quantization must stay within the
        # one-step error bound: |q2 - q1| <= |q1 - x| envelope.
        rng = np.random.default_rng(6)
        x = rng.normal(size=(4, 4, 3, 3)).astype(np.float32)
        cfg = QuantConfig(e_x=2, m_x=3, rounding="nearest")
        q1 = np.asarray(ref.mls_fake_quant(x, cfg))
        q2 = np.asarray(ref.mls_fake_quant(q1, cfg))
        err1 = np.abs(q1 - x).max()
        assert np.abs(q2 - q1).max() <= err1 + 1e-7
        # and with scales already aligned (elements exactly representable
        # against the same S_t), element-level idempotence does hold:
        q3 = np.asarray(ref.mls_fake_quant(q2, cfg))
        assert np.abs(q3 - q2).max() <= np.abs(q2 - q1).max() + 1e-7

    def test_sign_symmetry(self):
        rng = np.random.default_rng(7)
        x = rng.normal(size=(4, 4, 3, 3)).astype(np.float32)
        cfg = QuantConfig(e_x=2, m_x=2, rounding="nearest")
        q_pos = np.asarray(ref.mls_fake_quant(x, cfg))
        q_neg = np.asarray(ref.mls_fake_quant(-x, cfg))
        np.testing.assert_array_equal(q_pos, -q_neg)

    def test_zero_tensor(self):
        z = np.zeros((2, 3, 4, 4), np.float32)
        assert np.all(np.asarray(ref.mls_fake_quant(z, QuantConfig())) == 0)

    def test_disabled_is_identity(self):
        rng = np.random.default_rng(8)
        x = rng.normal(size=(2, 3, 4, 4)).astype(np.float32)
        q = np.asarray(ref.mls_fake_quant(x, QuantConfig(enabled=False)))
        np.testing.assert_array_equal(q, x)

    @pytest.mark.parametrize("grouping", ["none", "first", "second", "both"])
    def test_grouping_reduces_error(self, grouping):
        # per-group scaled error should never exceed ungrouped error by much
        rng = np.random.default_rng(9)
        x = (rng.normal(size=(8, 8, 4, 4)) * np.exp(rng.normal(size=(8, 8, 1, 1)) * 2)).astype(np.float32)
        cfg_g = QuantConfig(e_x=0, m_x=3, grouping=grouping, rounding="nearest")
        cfg_n = QuantConfig(e_x=0, m_x=3, grouping="none", rounding="nearest")
        are_g = float(ref.average_relative_error(x, cfg_g))
        are_n = float(ref.average_relative_error(x, cfg_n))
        if grouping == "both":
            assert are_g < are_n

    def test_more_mantissa_less_error(self):
        rng = np.random.default_rng(10)
        x = rng.normal(size=(8, 8, 3, 3)).astype(np.float32)
        ares = [float(ref.average_relative_error(x, QuantConfig(e_x=2, m_x=m)))
                for m in (1, 2, 3, 4)]
        assert all(a >= b - 1e-9 for a, b in zip(ares, ares[1:]))

    def test_more_exponent_less_error_ungrouped(self):
        rng = np.random.default_rng(11)
        x = (rng.normal(size=(8, 8, 3, 3)) * np.exp(rng.normal(size=(8, 8, 1, 1)))).astype(np.float32)
        ares = [float(ref.average_relative_error(
            x, QuantConfig(e_x=e, m_x=3, grouping="none"))) for e in (0, 1, 2)]
        assert ares[2] < ares[0]
