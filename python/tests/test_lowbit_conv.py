"""Integer-path convolution arithmetic (Eq. 6-8) vs the float fake-quant path.

The Pallas lowbit kernel and the jnp intra-group MAC reference both operate
on stored fields; their results must match the float path (product of
dequantized values summed per group) to f32 round-off.
"""

import dataclasses

import numpy as np
import pytest

pytest.importorskip("jax", reason="XLA-dependent: the lowbit kernels need jax")
pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.qconfig import QuantConfig, E2M4, E2M1
from compile.kernels import ref, lowbit_conv


def _fields_2d(x, cfg):
    f = ref.mls_quantize_fields(jnp.asarray(x), dataclasses.replace(cfg, grouping="first"))
    return {k: np.asarray(v) for k, v in f.items()}


def _fields_3d(a, cfg):
    # groups along axis 1 of (X, G, L): reduce axes (0, 2) = "second"
    f = ref.mls_quantize_fields(jnp.asarray(a), dataclasses.replace(cfg, grouping="second"))
    return {k: np.asarray(v) for k, v in f.items()}


def _run(cfg, G=8, L=9, X=16, seed=0):
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(G, L)) * np.exp(rng.normal(size=(G, 1)))).astype(np.float32)
    a = (rng.normal(size=(X, G, L)) * np.exp(rng.normal(size=(1, G, 1)))).astype(np.float32)
    wf, af = _fields_2d(w, cfg), _fields_3d(a, cfg)
    wfields = dict(x_man=wf["x_man"], x_exp_code=wf["x_exp_code"], sign=wf["sign"],
                   sg_exp_code=wf["sg_exp_code"].reshape(G), sg_man=wf["sg_man"].reshape(G))
    afields = dict(x_man=af["x_man"], x_exp_code=af["x_exp_code"], sign=af["sign"],
                   sg_exp_code=af["sg_exp_code"].reshape(G), sg_man=af["sg_man"].reshape(G))
    z = np.asarray(lowbit_conv.lowbit_conv_dot(wfields, afields, cfg))
    z_ref = (wf["q"][None] * af["q"]).sum(axis=(1, 2)) / (float(wf["s_t"]) * float(af["s_t"]))
    return z, z_ref, wf, af


@pytest.mark.parametrize("cfg", [E2M4, E2M1, QuantConfig(e_x=1, m_x=2),
                                 QuantConfig(e_x=0, m_x=4)])
def test_integer_path_matches_float_path(cfg):
    z, z_ref, _, _ = _run(cfg)
    scale = max(np.abs(z_ref).max(), 1e-9)
    assert np.abs(z - z_ref).max() / scale < 1e-5


def test_mg0_power_of_two_scales(cfg=QuantConfig(m_g=0)):
    z, z_ref, wf, af = _run(cfg, seed=3)
    assert np.all(wf["sg_man"] == 0)
    scale = max(np.abs(z_ref).max(), 1e-9)
    assert np.abs(z - z_ref).max() / scale < 1e-5


def test_intra_group_mac_ref_bitwidth():
    """Partial sums must fit the Sec. V-C analysis: product bits + log2(L)."""
    cfg = E2M4
    z, z_ref, wf, af = _run(cfg, G=4, L=9, X=8, seed=4)
    w2 = {k: wf[k] for k in ("x_man", "x_exp_code", "sign")}
    a2 = {k: af[k][0] for k in ("x_man", "x_exp_code", "sign")}
    p, _ = ref.intra_group_mac_ref(w2, a2, cfg.e_x, cfg.m_x)
    p = np.asarray(p)
    max_bits = cfg.product_bits + int(np.ceil(np.log2(9))) + 1
    assert np.abs(p).max() < 2 ** max_bits


@settings(max_examples=15, deadline=None)
@given(e_x=st.integers(0, 2), m_x=st.integers(1, 4),
       m_g=st.integers(0, 1), seed=st.integers(0, 1000),
       g=st.integers(1, 12), l=st.integers(1, 16))
def test_hypothesis_integer_path(e_x, m_x, m_g, seed, g, l):
    cfg = QuantConfig(e_x=e_x, m_x=m_x, m_g=m_g)
    z, z_ref, _, _ = _run(cfg, G=g, L=l, X=8, seed=seed)
    scale = max(np.abs(z_ref).max(), 1e-9)
    assert np.abs(z - z_ref).max() / scale < 1e-4
