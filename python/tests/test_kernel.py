"""Pallas kernel vs pure-jnp oracle: the CORE correctness signal.

The Pallas quantizer must be BIT-EXACT against ref.mls_fake_quant on
identical inputs, across shapes, groupings and bit-width configs --
including a hypothesis sweep over random shapes/configs.
"""

import numpy as np
import pytest

pytest.importorskip("jax", reason="XLA-dependent: the Pallas kernel needs jax")
pytest.importorskip("hypothesis", reason="property sweep needs hypothesis")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.qconfig import QuantConfig, NAMED
from compile.kernels import ref, mls_quant


def _rand(shape, seed, scale_axes=None):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=shape).astype(np.float32)
    if scale_axes:
        s = np.exp(rng.normal(size=tuple(
            d if i in scale_axes else 1 for i, d in enumerate(shape))) * 2)
        x = (x * s).astype(np.float32)
    return x


def _check(x, cfg, seed=0):
    rng = np.random.default_rng(seed + 1000)
    r = rng.uniform(-0.5, 0.5, x.shape).astype(np.float32)
    q_ref = np.asarray(ref.mls_fake_quant(jnp.asarray(x), cfg, jnp.asarray(r)))
    q_pal = np.asarray(mls_quant.mls_fake_quant(jnp.asarray(x), cfg, jnp.asarray(r)))
    np.testing.assert_array_equal(q_ref, q_pal)


@pytest.mark.parametrize("cfg_name", list(NAMED))
def test_named_configs_bit_exact(cfg_name):
    x = _rand((4, 8, 5, 5), 0, scale_axes=(0, 1))
    _check(x, NAMED[cfg_name])


@pytest.mark.parametrize("grouping", ["none", "first", "second", "both"])
def test_groupings_bit_exact(grouping):
    x = _rand((6, 10, 3, 3), 1, scale_axes=(0, 1))
    _check(x, QuantConfig(grouping=grouping))


@pytest.mark.parametrize("shape", [(1, 1, 1, 1), (2, 3, 1, 7), (16, 16, 3, 3),
                                   (32, 16, 8, 8), (5, 7, 4, 4)])
def test_shapes_bit_exact(shape):
    x = _rand(shape, 2)
    _check(x, QuantConfig())


def test_2d_tensor():
    # FC-style 2-D tensors must also group correctly
    x = _rand((12, 40), 3)
    for grouping in ("none", "first", "second", "both"):
        _check(x, QuantConfig(grouping=grouping))


def test_zero_tensor():
    z = np.zeros((3, 4, 2, 2), np.float32)
    _check(z, QuantConfig())


def test_huge_dynamic_range():
    x = _rand((4, 4, 3, 3), 4)
    x[0, 0] *= 1e8
    x[1, 1] *= 1e-8
    _check(x, QuantConfig())


def test_group_scales_match_ref():
    x = _rand((4, 6, 3, 3), 5, scale_axes=(0, 1))
    cfg = QuantConfig(rounding="nearest")
    x2d = jnp.asarray(x).reshape(24, 9)
    r2d = jnp.zeros_like(x2d)
    _q, sg = mls_quant.mls_fake_quant_2d(x2d, r2d, cfg)
    fields = ref.mls_quantize_fields(jnp.asarray(x), cfg)
    sg_ref = np.asarray(fields["s_g"]).reshape(24, 1)
    np.testing.assert_array_equal(np.asarray(sg), sg_ref)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 8), c=st.integers(1, 8),
    h=st.integers(1, 6), w=st.integers(1, 6),
    e_x=st.integers(0, 3), m_x=st.integers(1, 5),
    e_g=st.sampled_from([4, 8]), m_g=st.integers(0, 1),
    grouping=st.sampled_from(["none", "first", "second", "both"]),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_sweep(n, c, h, w, e_x, m_x, e_g, m_g, grouping, seed):
    cfg = QuantConfig(e_x=e_x, m_x=m_x, e_g=e_g, m_g=m_g, grouping=grouping)
    x = _rand((n, c, h, w), seed, scale_axes=(0, 1))
    _check(x, cfg, seed)
