//! Minimal, dependency-free stand-in for the `anyhow` error crate.
//!
//! The build environment for this repository only guarantees the Rust
//! toolchain itself (no crates.io registry access), so the subset of the
//! `anyhow` API the workspace uses is provided by this in-tree path crate:
//!
//! * `anyhow::Error` — an error value holding a message plus a flattened
//!   context/source chain (outermost first),
//! * `anyhow::Result<T>` — `Result<T, Error>`,
//! * the `anyhow!`, `bail!` and `ensure!` macros (format-string forms),
//! * the `Context` trait (`context` / `with_context`) on `Result` and
//!   `Option`.
//!
//! Display follows anyhow's convention: `{}` prints the outermost message,
//! `{:#}` prints the whole chain joined by `": "`.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error message plus its context/source chain, outermost first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap this error with an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// Iterate the message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `context` / `with_context` on fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(::std::format!($($arg)+))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        // Error::msg, not a format! path: stringify!($cond) may contain
        // braces (closures, struct literals) that would break a format
        // string.
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(::std::concat!(
                "condition failed: ",
                ::std::stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            $crate::bail!($($arg)+);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "inner detail")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = anyhow!("top {}", 7);
        assert_eq!(format!("{e}"), "top 7");
        let e = e.context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: top 7");
    }

    #[test]
    fn from_std_error_keeps_source_chain() {
        let e: Error = io_err().into();
        assert_eq!(format!("{e}"), "inner detail");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), _> = Err(io_err());
        let e = r.context("reading file").unwrap_err();
        assert_eq!(format!("{e:#}"), "reading file: inner detail");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
        assert_eq!(Some(1u32).context("fine").unwrap(), 1);
    }

    #[test]
    fn bail_and_ensure() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
    }

    #[test]
    fn ensure_without_message_allows_braces() {
        fn f(v: &[i32]) -> Result<()> {
            ensure!(v.iter().all(|e| *e > 0));
            Ok(())
        }
        assert!(f(&[1, 2]).is_ok());
        let msg = format!("{}", f(&[1, -2]).unwrap_err());
        assert!(msg.starts_with("condition failed: "), "{msg}");
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn chain_iterates_outermost_first() {
        let e = anyhow!("inner").context("mid").context("outer");
        let chain: Vec<&str> = e.chain().collect();
        assert_eq!(chain, vec!["outer", "mid", "inner"]);
    }
}
