//! CI smoke for the inference server: build a fresh `cnn_t` behind the
//! quantize-once weight/panel cache, require the cached served forward
//! to be **bit-identical** to the `eval_logits` oracle (logits bits AND
//! every audit counter), then round-trip the framed protocol end to end
//! over both transports — an in-memory jsonl stream (FIFO order, exact
//! logits through JSON, error containment for garbage frames) and a TCP
//! loopback connection. Exits nonzero on any mismatch; CI also greps the
//! `serve bit-identity OK` line so a silently-skipped check cannot pass.
//!
//! Run with: `cargo run --release --example serve_smoke`

use std::collections::BTreeMap;
use std::io::Cursor;
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use mls_train::data::{streams, DatasetConfig, SynthCifar};
use mls_train::serve::{serve_stream, serve_tcp, ServeOptions, ServedModel};
use mls_train::util::frame;
use mls_train::util::json::Json;

const CFG: &str = "e2m4_gnc_eg8mg1_sr";

fn req_frame(id: u64, image: &[f32]) -> anyhow::Result<Vec<u8>> {
    let mut m = BTreeMap::new();
    m.insert("id".to_string(), Json::Num(id as f64));
    m.insert(
        "image".to_string(),
        Json::Arr(image.iter().map(|&v| Json::Num(v as f64)).collect()),
    );
    let mut buf = Vec::new();
    frame::write_frame(&mut buf, Json::Obj(m).to_string_compact().as_bytes())?;
    Ok(buf)
}

fn main() -> anyhow::Result<()> {
    println!("== serve smoke (quantize-once cache, framed protocol, TCP loopback) ==");
    let threads = mls_train::util::parallel::num_threads();
    let mut served = ServedModel::fresh("cnn_t", CFG, 9, threads)?;
    let elems = served.input_elems();
    let classes = served.classes();
    let ds = SynthCifar::new(DatasetConfig { noise: 1.0, seed: 5, ..Default::default() });
    let (images, _) = ds.batch(4, streams::TEST, 0);

    // 1. bit-identity: warm (quantize + pack once), then compare the
    // CACHED steady-state forward against the heap-path oracle
    let mut logits = Vec::new();
    served.infer_batch(&images, 4, &mut logits);
    served.infer_batch(&images, 4, &mut logits);
    let (oracle, oracle_audit) = served.model().eval_logits(&images, 4);
    let bad_bits = logits
        .iter()
        .zip(&oracle)
        .filter(|(a, b)| a.to_bits() != b.to_bits())
        .count();
    anyhow::ensure!(bad_bits == 0, "{bad_bits} served logit(s) differ from the eval oracle");
    anyhow::ensure!(
        served.last_audit() == &oracle_audit,
        "served audit counters differ from the eval oracle"
    );
    println!(
        "  serve bit-identity OK (batch 4, {} logits + all audit counters, {threads} threads)",
        logits.len()
    );

    // 2. jsonl transport: 3 requests + 1 garbage frame + shutdown; FIFO
    // responses, exact logits through JSON, garbage answered not fatal
    let mut input = Vec::new();
    for (i, id) in [5u64, 6, 7].iter().enumerate() {
        input.extend_from_slice(&req_frame(*id, &images[i * elems..(i + 1) * elems])?);
    }
    frame::write_frame(&mut input, b"{definitely not json")?;
    frame::write_frame(&mut input, br#"{"cmd": "shutdown"}"#)?;
    let opts = ServeOptions { batch_max: 2, batch_wait: Duration::ZERO, ..Default::default() };
    let mut out = Vec::new();
    let stats = serve_stream(&mut served, Cursor::new(input), &mut out, &opts)?;
    anyhow::ensure!(stats.requests == 3, "expected 3 served requests, got {}", stats.requests);

    let mut reader = &out[..];
    let mut resps = Vec::new();
    while let Some(p) = frame::read_frame(&mut reader, 1 << 22)? {
        resps.push(Json::parse(std::str::from_utf8(&p)?).map_err(|e| anyhow::anyhow!("{e}"))?);
    }
    anyhow::ensure!(resps.len() == 4, "expected 3 answers + 1 error, got {}", resps.len());
    for (i, (resp, want_id)) in resps.iter().zip([5u64, 6, 7]).enumerate() {
        let id = resp.get("id").and_then(|v| v.as_f64()).unwrap_or(-1.0) as u64;
        anyhow::ensure!(id == want_id, "response {i}: id {id}, want {want_id} (FIFO)");
        let n = resp.get("batch").and_then(|v| v.as_f64()).unwrap_or(0.0) as usize;
        anyhow::ensure!(n >= 1, "response {i} reports batch {n}");
        let got = resp
            .get("logits")
            .ok_or_else(|| anyhow::anyhow!("response {i} has no logits"))?
            .f32s()
            .map_err(|e| anyhow::anyhow!("response {i} logits: {e}"))?;
        anyhow::ensure!(got.len() == classes, "response {i}: {} logits", got.len());
    }
    anyhow::ensure!(
        resps[3].get("error").and_then(|v| v.as_str()).is_some_and(|e| e.contains("JSON")),
        "the garbage frame must be answered with a JSON error"
    );
    println!("  jsonl transport OK ({})", stats.summary());

    // 3. TCP loopback: one connection, one request, clean shutdown
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let img = images[..elems].to_vec();
    let frame0 = req_frame(42, &img)?;
    let client = std::thread::spawn(move || -> anyhow::Result<u64> {
        use std::io::Write;
        let mut s = TcpStream::connect(addr)?;
        s.write_all(&frame0)?;
        s.flush()?;
        let payload = frame::read_frame(&mut s, 1 << 22)?
            .ok_or_else(|| anyhow::anyhow!("connection closed before the response"))?;
        let resp = Json::parse(std::str::from_utf8(&payload)?)
            .map_err(|e| anyhow::anyhow!("response is not JSON: {e}"))?;
        let mut shutdown = Vec::new();
        frame::write_frame(&mut shutdown, br#"{"cmd": "shutdown"}"#)?;
        s.write_all(&shutdown)?;
        s.flush()?;
        resp.get("id")
            .and_then(|v| v.as_f64())
            .map(|v| v as u64)
            .ok_or_else(|| anyhow::anyhow!("response has no id"))
    });
    let stats = serve_tcp(&mut served, listener, &ServeOptions::default())?;
    let id = client.join().map_err(|_| anyhow::anyhow!("TCP client panicked"))??;
    anyhow::ensure!(id == 42, "TCP response id {id}, want 42");
    anyhow::ensure!(stats.requests == 1, "TCP served {} requests, want 1", stats.requests);
    println!("  tcp transport OK ({})", stats.summary());

    println!("OK");
    Ok(())
}
