//! CI smoke for the crash-safe training loop: inject a deterministic
//! crash right after the step-3 checkpoint, resume from the checkpoint,
//! and require the resumed run to be **bit-identical** to an
//! uninterrupted baseline (losses, lr, evals, every parameter bit, the
//! audit roll-up, and the test metrics). Then exercise each
//! `on_divergence` health policy against an injected NaN gradient:
//! `abort` must stop and mark the run diverged, `rollback` must recover
//! onto the exact clean trajectory, and `halve_lr` must recover onto a
//! *different* (half-lr) trajectory. Exits nonzero on any mismatch,
//! failing the CI step, which also greps the bit-identity line.
//!
//! Artifacts (checkpoints + manifests + audit streams) land under
//! `runs/fault/`, where CI schema-validates them.
//!
//! Run with: `cargo run --release --example fault_tolerance_smoke`

use mls_train::coordinator::{trainer, TrainConfig};

const STEPS: u64 = 6;
const CRASH_AT: u64 = 3;

fn config(out_dir: Option<&str>) -> TrainConfig {
    let mut c = TrainConfig::default();
    c.model = "cnn_t".to_string();
    c.cfg_name = "e2m4_gnc_eg8mg1_sr".to_string();
    c.steps = STEPS;
    c.batch = 8;
    c.eval_every = 2;
    c.eval_batches = 2;
    c.lr.base = 0.05;
    c.lr.milestones = vec![];
    c.optimizer = "momentum".to_string();
    c.data.noise = 1.0;
    c.data.label_noise = 0.0;
    c.checkpoint_every = 1;
    c.out_dir = out_dir.map(str::to_string);
    c
}

fn assert_bit_identical(
    a: &trainer::TrainResult,
    b: &trainer::TrainResult,
) -> anyhow::Result<()> {
    anyhow::ensure!(a.metrics.steps.len() == b.metrics.steps.len(), "step row count differs");
    for (x, y) in a.metrics.steps.iter().zip(&b.metrics.steps) {
        anyhow::ensure!(
            x.step == y.step
                && x.lr.to_bits() == y.lr.to_bits()
                && x.loss.to_bits() == y.loss.to_bits()
                && x.acc.to_bits() == y.acc.to_bits(),
            "step {} row differs bitwise",
            x.step
        );
    }
    anyhow::ensure!(a.metrics.evals.len() == b.metrics.evals.len(), "eval row count differs");
    for (x, y) in a.metrics.evals.iter().zip(&b.metrics.evals) {
        anyhow::ensure!(
            x.step == y.step
                && x.loss.to_bits() == y.loss.to_bits()
                && x.acc.to_bits() == y.acc.to_bits(),
            "eval row at step {} differs bitwise",
            x.step
        );
    }
    anyhow::ensure!(a.final_state.len() == b.final_state.len(), "state length differs");
    let diff = a
        .final_state
        .iter()
        .zip(&b.final_state)
        .filter(|(x, y)| x.to_bits() != y.to_bits())
        .count();
    anyhow::ensure!(diff == 0, "{diff} parameter(s) differ bitwise");
    anyhow::ensure!(a.audit_totals == b.audit_totals, "audit roll-up differs");
    anyhow::ensure!(a.audit_steps == b.audit_steps, "audit step count differs");
    anyhow::ensure!(a.test_loss.to_bits() == b.test_loss.to_bits(), "test loss differs");
    anyhow::ensure!(a.test_acc.to_bits() == b.test_acc.to_bits(), "test acc differs");
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("== fault-tolerance smoke (crash + bit-identical resume, health policies) ==");
    // start from a clean slate so a leftover complete checkpoint from a
    // previous invocation cannot swallow the injected crash
    let _ = std::fs::remove_dir_all("runs/fault");

    // 1. uninterrupted baseline
    let baseline_dir = "runs/fault/baseline";
    let clean = trainer::train_native(&config(Some(baseline_dir)))?;
    anyhow::ensure!(!clean.diverged, "baseline diverged");

    // 2. crash right after the step-{CRASH_AT} checkpoint...
    let crash_dir = "runs/fault/crash_resume";
    let mut c = config(Some(crash_dir));
    c.fault = Some(format!("crash_after_ckpt@step{CRASH_AT}"));
    match trainer::train_native(&c) {
        Err(e) if format!("{e:#}").contains("MLS_FAULT crash injected") => {}
        Err(e) => anyhow::bail!("crash run failed for the wrong reason: {e:#}"),
        Ok(_) => anyhow::bail!("injected crash did not fire"),
    }
    println!("  crash injected after checkpoint at step {CRASH_AT}");

    // ...and resume from the surviving checkpoint
    let resumed = trainer::train_native(&c)?;
    anyhow::ensure!(
        resumed.resumed_from == Some(CRASH_AT + 1),
        "expected resume at step {}, got {:?}",
        CRASH_AT + 1,
        resumed.resumed_from
    );
    anyhow::ensure!(
        resumed.steps_executed == STEPS - (CRASH_AT + 1),
        "resume must execute only the remaining steps"
    );
    assert_bit_identical(&clean, &resumed)?;
    println!(
        "  bit-identical resume OK (resumed at step {}, executed {} of {} steps)",
        CRASH_AT + 1,
        resumed.steps_executed,
        STEPS
    );

    // 3. health policies against an injected NaN gradient
    let mut abort = config(Some("runs/fault/policy_abort"));
    abort.on_divergence = "abort".to_string();
    abort.fault = Some("nan_grad@step2".to_string());
    let r = trainer::train_native(&abort)?;
    anyhow::ensure!(r.diverged && r.rollbacks == 0, "abort policy must stop the run");
    println!("  on_divergence=abort OK (diverged at step 2, health record streamed)");

    let mut clean_rb = config(Some("runs/fault/policy_rollback_clean"));
    clean_rb.on_divergence = "rollback".to_string();
    let clean_rb = trainer::train_native(&clean_rb)?;
    let mut rb = config(Some("runs/fault/policy_rollback"));
    rb.on_divergence = "rollback".to_string();
    rb.fault = Some("nan_grad@step2".to_string());
    let r = trainer::train_native(&rb)?;
    anyhow::ensure!(!r.diverged && r.rollbacks == 1, "rollback policy must recover");
    assert_bit_identical(&clean_rb, &r)?;
    println!("  on_divergence=rollback OK (1 rollback, recovered bit-identically)");

    let mut hl = config(Some("runs/fault/policy_halve_lr"));
    hl.on_divergence = "halve_lr".to_string();
    hl.fault = Some("nan_grad@step2".to_string());
    let r = trainer::train_native(&hl)?;
    anyhow::ensure!(!r.diverged && r.rollbacks == 1, "halve_lr policy must recover");
    let base = hl.lr.base;
    anyhow::ensure!(
        r.metrics.steps[2].lr.to_bits() == (base * 0.5).to_bits(),
        "replayed step must run at half lr"
    );
    anyhow::ensure!(
        r.final_state != clean_rb.final_state,
        "halve_lr must change the trajectory"
    );
    println!("  on_divergence=halve_lr OK (replay at half lr, trajectory moved)");

    println!("OK");
    Ok(())
}
