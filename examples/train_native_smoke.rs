//! CI smoke for the native Alg. 1 trainer: multi-step low-bit training
//! runs on synthetic CIFAR must complete with zero external dependencies
//! (no PJRT, no artifacts), and the loss must be finite and DECREASING —
//! for the fp32 baseline, for the quantized `<2,4>` headline config on
//! the `cnn_t` chain model, and for the aggressive `<2,1>` config on the
//! `resnet_t` residual module-graph model (skip-add joins and 1x1
//! projection shortcuts all running Alg. 1 forward/wgrad/dgrad on the
//! pass-generic packed-GEMM engine). Exits nonzero otherwise, failing
//! the CI step.
//!
//! Run with: `cargo run --release --example train_native_smoke`

use mls_train::coordinator::{trainer, TrainConfig};

fn run(model: &str, cfg_name: &str, steps: u64, lr: f32) -> anyhow::Result<(f64, f64, f32)> {
    let mut c = TrainConfig::default();
    c.model = model.to_string();
    c.cfg_name = cfg_name.to_string();
    c.steps = steps;
    c.batch = 16;
    c.eval_every = 0;
    c.eval_batches = 4;
    c.lr.base = lr;
    c.lr.milestones = vec![];
    c.data.noise = 1.0;
    c.data.label_noise = 0.0;
    c.out_dir = None;
    let r = trainer::train_native(&c)?;
    anyhow::ensure!(!r.diverged, "{model}/{cfg_name}: training diverged");
    for row in &r.metrics.steps {
        anyhow::ensure!(
            row.loss.is_finite(),
            "{model}/{cfg_name}: non-finite loss {} at step {}",
            row.loss,
            row.step
        );
    }
    let k = 3usize.min(r.metrics.steps.len());
    let first: f64 =
        r.metrics.steps[..k].iter().map(|s| s.loss as f64).sum::<f64>() / k as f64;
    let last = r.metrics.final_loss(k);
    anyhow::ensure!(
        last < first,
        "{model}/{cfg_name}: loss did not decrease over {steps} steps ({first:.4} -> {last:.4})"
    );
    Ok((first, last, r.test_acc))
}

fn main() -> anyhow::Result<()> {
    println!("== native Alg. 1 train smoke (module graph, synthetic CIFAR, no PJRT) ==");
    for (model, cfg, steps, lr) in [
        ("cnn_t", "fp32", 12u64, 0.05f32),
        ("cnn_t", "e2m4_gnc_eg8mg1_sr", 20, 0.05),
        ("resnet_t", "e2m1_gnc_eg8mg1_sr", 18, 0.04),
    ] {
        let (first, last, acc) = run(model, cfg, steps, lr)?;
        println!(
            "  {model:<9} {cfg:<22} {steps:>3} steps: loss {first:.4} -> {last:.4} (decreasing), \
             test acc {acc:.3}"
        );
    }
    println!("OK");
    Ok(())
}
