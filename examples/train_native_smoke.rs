//! CI smoke for the native Alg. 1 trainer: a multi-step low-bit training
//! run on synthetic CIFAR must complete with zero external dependencies
//! (no PJRT, no artifacts), and the loss must be finite and DECREASING —
//! both for the fp32 baseline and for the quantized `<2,4>` headline
//! config whose forward/wgrad/dgrad convs all run on the pass-generic
//! packed-GEMM engine. Exits nonzero otherwise, failing the CI step.
//!
//! Run with: `cargo run --release --example train_native_smoke`

use mls_train::coordinator::{trainer, TrainConfig};

fn run(cfg_name: &str, steps: u64) -> anyhow::Result<(f64, f64, f32)> {
    let mut c = TrainConfig::default();
    c.model = "cnn_t".to_string();
    c.cfg_name = cfg_name.to_string();
    c.steps = steps;
    c.batch = 16;
    c.eval_every = 0;
    c.eval_batches = 4;
    c.lr.base = 0.05;
    c.lr.milestones = vec![];
    c.data.noise = 1.0;
    c.data.label_noise = 0.0;
    c.out_dir = None;
    let r = trainer::train_native(&c)?;
    anyhow::ensure!(!r.diverged, "{cfg_name}: training diverged");
    for row in &r.metrics.steps {
        anyhow::ensure!(
            row.loss.is_finite(),
            "{cfg_name}: non-finite loss {} at step {}",
            row.loss,
            row.step
        );
    }
    let k = 3usize.min(r.metrics.steps.len());
    let first: f64 =
        r.metrics.steps[..k].iter().map(|s| s.loss as f64).sum::<f64>() / k as f64;
    let last = r.metrics.final_loss(k);
    anyhow::ensure!(
        last < first,
        "{cfg_name}: loss did not decrease over {steps} steps ({first:.4} -> {last:.4})"
    );
    Ok((first, last, r.test_acc))
}

fn main() -> anyhow::Result<()> {
    println!("== native Alg. 1 train smoke (cnn_t, synthetic CIFAR, no PJRT) ==");
    for (cfg, steps) in [("fp32", 12u64), ("e2m4_gnc_eg8mg1_sr", 20)] {
        let (first, last, acc) = run(cfg, steps)?;
        println!(
            "  {cfg:<22} {steps:>3} steps: loss {first:.4} -> {last:.4} (decreasing), test acc {acc:.3}"
        );
    }
    println!("OK");
    Ok(())
}
