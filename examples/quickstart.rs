//! Quickstart: the 60-second tour of the framework.
//!
//! 1. quantize a tensor to the MLS format and inspect it,
//! 2. run the bit-accurate integer-path convolution vs the float path,
//! 3. load an AOT train-step artifact and take a few real training steps,
//! 4. print the headline energy numbers.
//!
//! Run with: `cargo run --release --example quickstart`
//! (needs `make artifacts` for step 3; steps 1-2 and 4 work without).

use mls_train::arith::conv::{conv2d_f32, lowbit_conv};
use mls_train::data::{streams, SynthCifar};
use mls_train::hw::report;
use mls_train::hw::units::EnergyModel;
use mls_train::mls::format::EmFormat;
use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::runtime::Engine;
use mls_train::util::rng::Pcg32;
use mls_train::util::stats;

fn main() -> anyhow::Result<()> {
    println!("== 1. MLS dynamic quantization (paper Alg. 2) ==");
    let mut rng = Pcg32::seeded(42);
    let shape = [8usize, 16, 5, 5];
    let x = mls_train::util::prop::grouped_tensor(&mut rng, shape);
    let cfg = QuantConfig::default(); // <2,4> elements, <8,1> groups, nc
    let offsets = rng.rounding_offsets(x.len());
    let t = quantize(&x, &shape, &cfg, &offsets);
    let q = t.dequantize();
    println!(
        "  {} elements as {}: {} bits/elem, {:.2}x smaller than f32, ARE {:.4}",
        t.len(),
        cfg.name(),
        cfg.element_bits(),
        t.compression_ratio(),
        stats::average_relative_error(&x, &q),
    );

    println!("\n== 2. integer-path convolution (paper Eq. 6-8) ==");
    let wshape = [8usize, 16, 3, 3];
    let w = mls_train::util::prop::grouped_tensor(&mut rng, wshape);
    let mut ncfg = cfg;
    ncfg.rounding = Rounding::Nearest;
    let tw = quantize(&w, &wshape, &ncfg, &[]);
    let ta = quantize(&x, &shape, &ncfg, &[]);
    let out = lowbit_conv(&tw, &ta, 1, 1);
    let (zf, _) = conv2d_f32(&tw.dequantize(), wshape, &ta.dequantize(), shape, 1, 1);
    let max_rel = out
        .z
        .iter()
        .zip(&zf)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max)
        / zf.iter().fold(0.0f32, |m, v| m.max(v.abs()));
    println!(
        "  integer datapath == float path within {:.2e}; peak accumulator {} bits \
         (paper: i32 suffices for <2,4>)",
        max_rel, out.peak_acc_bits
    );

    println!("\n== 3. real training steps through the AOT artifact ==");
    match Engine::from_dir("artifacts") {
        Ok(mut engine) => {
            let model = "resnet_t";
            let cfg_name = "e2m4_gnc_eg8mg1_sr";
            let ds = SynthCifar::new(Default::default());
            let batch = engine.manifest.model(model)?.batch;
            let mut state = engine.manifest.load_init(model)?;
            for step in 0..5 {
                let (images, labels) = ds.batch(batch, streams::TRAIN, step);
                let out = engine.train_step(
                    model, cfg_name, &mut state, &images, &labels, step as i32, 0.05,
                )?;
                println!("  step {step}: loss {:.4} acc {:.2}", out.loss, out.acc);
            }
        }
        Err(e) => println!("  (skipped: {e:#})"),
    }

    println!("\n== 4. energy headline (paper Eq. 12 / Table VI) ==");
    let em = EnergyModel::fitted();
    print!("{}", report::eq12(&em, EmFormat::new(2, 4)));
    print!("{}", report::ratios(64, EmFormat::new(2, 4), &em)?);
    Ok(())
}
