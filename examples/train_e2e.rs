//! End-to-end validation driver (DESIGN.md "End-to-end validation").
//!
//! Trains the scaled residual CNN on synthcifar for several hundred steps
//! through the FULL stack — Rust coordinator -> PJRT -> AOT HLO containing
//! the Pallas-quantized train step — under fp32 and two MLS configs, logs
//! the loss curves to `runs/*.csv`, and prints the accuracy gaps (the
//! Table II headline shape). Results are recorded in EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example train_e2e -- [steps] [model]`

use mls_train::coordinator::{trainer, TrainConfig};
use mls_train::runtime::Engine;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);
    let model = args.get(2).cloned().unwrap_or_else(|| "resnet_t".to_string());

    let mut engine = Engine::from_dir("artifacts")?;
    let configs = ["fp32", "e2m4_gnc_eg8mg1_sr", "e2m1_gnc_eg8mg1_sr"];

    println!("end-to-end training: {model}, {steps} steps x {} configs", configs.len());
    let mut results = Vec::new();
    for cfg_name in configs {
        let mut c = TrainConfig::default();
        c.backend = mls_train::coordinator::Backend::Pjrt; // this driver exercises the FULL PJRT stack
        c.model = model.clone();
        c.cfg_name = cfg_name.to_string();
        c.steps = steps;
        c.eval_every = (steps / 6).max(1);
        c.out_dir = Some("runs".to_string());
        let t0 = std::time::Instant::now();
        let r = trainer::train(&mut engine, &c)?;
        println!(
            "  {:<24} final-loss {:.4}  test-acc {:.3}  ({:.1} s, {:.0} ms/step, curve: runs/{}_{}_s0.csv)",
            cfg_name,
            r.metrics.final_loss(20),
            r.test_acc,
            t0.elapsed().as_secs_f64(),
            r.metrics.mean_step_ms(),
            model,
            cfg_name,
        );
        results.push((cfg_name, r));
    }

    let base = results[0].1.test_acc;
    println!("\naccuracy drops vs fp32 (paper claim: <1% for the headline formats):");
    for (name, r) in &results[1..] {
        println!("  {:<24} {:+.2}%", name, (base - r.test_acc) * 100.0);
    }
    println!(
        "\nloss curves (first -> last): {}",
        results
            .iter()
            .map(|(n, r)| format!(
                "{}: {:.3}->{:.3}",
                n,
                r.metrics.steps.first().map(|s| s.loss).unwrap_or(f32::NAN),
                r.metrics.final_loss(20)
            ))
            .collect::<Vec<_>>()
            .join("  ")
    );
    Ok(())
}
