//! File-level MLS codec demo: quantize raw f32 data under a sweep of
//! formats and print the storage/error trade-off curve — the quickest way
//! to see what <E, M> buys on YOUR data.
//!
//! Run with: `cargo run --release --example quantize_file -- [file.f32]`
//! (no file: uses a synthetic weight-like tensor)

use mls_train::mls::quantizer::{quantize, QuantConfig, Rounding};
use mls_train::mls::{format::EmFormat, Grouping};
use mls_train::util::rng::Pcg32;
use mls_train::util::stats;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let x: Vec<f32> = match args.get(1) {
        Some(path) => std::fs::read(path)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
        None => {
            let mut rng = Pcg32::seeded(7);
            mls_train::util::prop::grouped_tensor(&mut rng, [16, 16, 3, 3])
        }
    };
    // pad to a [G, L] 2-D view for grouping
    let g = 64.min(x.len());
    let l = x.len() / g;
    let x = &x[..g * l];
    let shape = [g, l, 1, 1];
    println!("{} values, grouped {}x{}", x.len(), g, l);
    println!(
        "{:<8} {:>6} {:>12} {:>12} {:>10}",
        "format", "bits", "ARE(none)", "ARE(group)", "compress"
    );
    for (e, m) in [(0u32, 3u32), (0, 7), (1, 2), (2, 1), (2, 4), (3, 4), (5, 2)] {
        let mk = |grouping| QuantConfig {
            element: EmFormat::new(e, m),
            group: EmFormat::new(8, 1),
            grouping,
            rounding: Rounding::Nearest,
            enabled: true,
        };
        let t_n = quantize(x, &shape, &mk(Grouping::None), &[]);
        let t_g = quantize(x, &shape, &mk(Grouping::First), &[]);
        println!(
            "<{e},{m}>   {:>6} {:>12.5} {:>12.5} {:>9.2}x",
            1 + e + m,
            stats::average_relative_error(x, &t_n.dequantize()),
            stats::average_relative_error(x, &t_g.dequantize()),
            t_g.compression_ratio(),
        );
    }
    println!("\n(the paper's insight in one table: group scaling buys what ~2 extra\n\
              exponent bits would, at a fraction of the hardware cost)");
    Ok(())
}
