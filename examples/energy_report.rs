//! Full hardware-energy report for any supported network — the Table V /
//! Table VI / Eq. 12 / Fig. 2 pipeline in one binary.
//!
//! Run with: `cargo run --release --example energy_report -- [network] [batch]`
//! Networks: resnet18 resnet34 resnet20 vgg16 googlenet resnet_t cnn_s

use mls_train::hw::report;
use mls_train::hw::units::EnergyModel;
use mls_train::mls::format::EmFormat;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let net = args.get(1).cloned().unwrap_or_else(|| "resnet34".to_string());
    let batch: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(64);
    let em = EnergyModel::fitted();
    let fmt = EmFormat::new(2, 4);

    println!("{}", report::table5(&em));
    println!("{}", report::table6(&net, batch, fmt, &em)?);
    println!("{}", report::eq12(&em, fmt));
    println!("{}", report::fig2(&net, batch, fmt, &em, None)?);
    println!("{}", report::ratios(batch, fmt, &em)?);
    Ok(())
}
